//! Deterministic PRNGs (offline build: no `rand` crate).
//!
//! [`Pcg64`] is a PCG-XSH-RR style generator — fast, statistically
//! solid for simulation workloads, and fully reproducible from a seed.
//! Helpers cover the distributions the workload models need: uniform
//! ranges, normals (Box-Muller), log-uniform task runtimes.

/// PCG-XSH-RR 64/32 with 64-bit output composition.
#[derive(Clone, Debug)]
pub struct Pcg64 {
    state: u64,
    inc: u64,
}

impl Pcg64 {
    pub fn new(seed: u64) -> Self {
        let mut p = Pcg64 { state: 0, inc: (seed << 1) | 1 };
        p.next_u32();
        p.state = p.state.wrapping_add(0x853c49e6748fea9b ^ seed);
        p.next_u32();
        p
    }

    fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old
            .wrapping_mul(6364136223846793005)
            .wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0);
        // Multiply-shift; bias negligible for simulation use.
        ((self.next_u64() as u128 * n as u128) >> 64) as u64
    }

    /// Uniform integer in [lo, hi] inclusive.
    pub fn range_u64(&mut self, lo: u64, hi: u64) -> u64 {
        lo + self.below(hi - lo + 1)
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-300);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Normal with mean/sd.
    pub fn normal_ms(&mut self, mean: f64, sd: f64) -> f64 {
        mean + sd * self.normal()
    }

    /// Log-uniform in [lo, hi] — the shape of the paper's per-task
    /// runtimes ("5 s to 160 s depending on the number of diffraction
    /// spots": a few slow tasks, many fast ones).
    pub fn log_uniform(&mut self, lo: f64, hi: f64) -> f64 {
        assert!(lo > 0.0 && hi >= lo);
        (self.range_f64(lo.ln(), hi.ln())).exp()
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_from_seed() {
        let mut a = Pcg64::new(42);
        let mut b = Pcg64::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Pcg64::new(43);
        assert_ne!(Pcg64::new(42).next_u64(), c.next_u64());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Pcg64::new(1);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn uniform_mean_is_half() {
        let mut r = Pcg64::new(2);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "{mean}");
    }

    #[test]
    fn below_respects_bound() {
        let mut r = Pcg64::new(3);
        let mut seen = [false; 7];
        for _ in 0..1000 {
            seen[r.below(7) as usize] = true;
        }
        assert!(seen.iter().all(|&s| s), "all residues hit");
    }

    #[test]
    fn normal_moments() {
        let mut r = Pcg64::new(4);
        let n = 100_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "{mean}");
        assert!((var - 1.0).abs() < 0.03, "{var}");
    }

    #[test]
    fn log_uniform_in_range() {
        let mut r = Pcg64::new(5);
        for _ in 0..10_000 {
            let x = r.log_uniform(5.0, 160.0);
            assert!((5.0..=160.0).contains(&x));
        }
    }

    #[test]
    fn range_u64_inclusive() {
        let mut r = Pcg64::new(7);
        for _ in 0..1000 {
            let x = r.range_u64(3, 5);
            assert!((3..=5).contains(&x));
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Pcg64::new(6);
        let mut v: Vec<u32> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
        assert_ne!(v, (0..50).collect::<Vec<_>>()); // astronomically unlikely
    }
}
