//! In-tree utilities (offline build: no external crates).
pub mod json;
pub mod prng;
pub mod bench;
pub mod args;
pub mod par;

/// Schedule count for the property suites: `XSTAGE_PROP_SCHEDULES` if
/// set (CI pins it explicitly), else `default`. Lets a local
/// `XSTAGE_PROP_SCHEDULES=25 cargo test -q` run a fast pass without
/// weakening the pinned CI sweep.
///
/// Panics on an unparseable value — a typo silently falling back to
/// the default would defeat the pin.
pub fn prop_schedules(default: u64) -> u64 {
    match std::env::var("XSTAGE_PROP_SCHEDULES") {
        Ok(v) => v
            .trim()
            .parse()
            .unwrap_or_else(|e| panic!("XSTAGE_PROP_SCHEDULES={v:?} is not a count: {e}")),
        Err(_) => default,
    }
}
