//! In-tree utilities (offline build: no external crates).
pub mod json;
pub mod prng;
pub mod bench;
pub mod args;
