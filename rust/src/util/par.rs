//! The parallel experiment-matrix runner.
//!
//! Every experiment sweeps a matrix of *independent* seeded points:
//! each point builds its own `SimCore` from its own seed, so points
//! share no state and their results cannot observe each other. That
//! makes the matrix embarrassingly parallel in *host* time while every
//! per-point result stays bit-identical to a serial run — the only
//! thing that changes is which OS thread happened to execute a point.
//!
//! [`matrix_map`] fans the points across `std::thread::scope` workers
//! (no new dependencies — the workspace builds offline) and collects
//! results **in point order**, so downstream tables and series are
//! byte-identical regardless of scheduling. The worker count comes
//! from `XSTAGE_JOBS`; `1` (the default) takes a plain serial loop —
//! literally today's code path, not a one-thread pool.
//!
//! What must stay serial stays serial: anything that folds *across*
//! points (the chaos table's calm-P99 baseline column, fig12/13's
//! first-point speedup base, ingest's cross-point series) runs in a
//! second, ordinary loop over the collected results.
//!
//! **Host-time caveat.** Wall-clock fields measured inside a point
//! (`host_secs` and friends) remain meaningful per point, but points
//! now time-share cores; see EXPERIMENTS.md "Host-time measurement
//! under the parallel runner". Virtual-time outputs are unaffected.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// Worker count for experiment matrices: `XSTAGE_JOBS` if set, else 1
/// (serial). Panics on an unparseable value — a typo silently falling
/// back to serial would defeat a CI pin — and clamps 0 up to 1.
pub fn jobs_from_env() -> usize {
    match std::env::var("XSTAGE_JOBS") {
        Ok(v) => v
            .trim()
            .parse::<usize>()
            .unwrap_or_else(|e| panic!("XSTAGE_JOBS={v:?} is not a worker count: {e}"))
            .max(1),
        Err(_) => 1,
    }
}

/// Map `f` over `points`, returning results in point order. With
/// `XSTAGE_JOBS` <= 1 (or fewer than two points) this is exactly a
/// serial `iter().map(f).collect()` on the calling thread.
pub fn matrix_map<P, R, F>(points: Vec<P>, f: F) -> Vec<R>
where
    P: Send,
    R: Send,
    F: Fn(P) -> R + Sync,
{
    matrix_map_jobs(points, jobs_from_env(), f)
}

/// [`matrix_map`] with an explicit worker count (tests drive both
/// paths without touching the process environment).
pub fn matrix_map_jobs<P, R, F>(points: Vec<P>, jobs: usize, f: F) -> Vec<R>
where
    P: Send,
    R: Send,
    F: Fn(P) -> R + Sync,
{
    let n = points.len();
    if jobs <= 1 || n <= 1 {
        return points.into_iter().map(f).collect();
    }
    // Claim indices atomically, deposit each result in its own slot:
    // collection order is the vector order, never completion order.
    let slots: Vec<Mutex<Option<R>>> = (0..n).map(|_| Mutex::new(None)).collect();
    let items: Vec<Mutex<Option<P>>> = points.into_iter().map(|p| Mutex::new(Some(p))).collect();
    let next = AtomicUsize::new(0);
    std::thread::scope(|s| {
        for _ in 0..jobs.min(n) {
            s.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let p = items[i].lock().unwrap().take().expect("point claimed twice");
                let r = f(p);
                *slots[i].lock().unwrap() = Some(r);
            });
        }
    });
    slots
        .into_iter()
        .map(|s| s.into_inner().unwrap().expect("worker died before depositing"))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn serial_and_parallel_agree_in_order() {
        let points: Vec<u64> = (0..37).collect();
        let square = |p: u64| p * p;
        let serial = matrix_map_jobs(points.clone(), 1, square);
        let parallel = matrix_map_jobs(points, 4, square);
        assert_eq!(serial, parallel);
        assert_eq!(serial[10], 100);
    }

    #[test]
    fn more_jobs_than_points_is_fine() {
        assert_eq!(matrix_map_jobs(vec![7usize], 16, |p| p + 1), vec![8]);
        let empty: Vec<u32> = Vec::new();
        assert_eq!(matrix_map_jobs(empty, 8, |p| p), Vec::<u32>::new());
    }
}
