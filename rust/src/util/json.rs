//! Minimal JSON parser (offline build: no `serde_json`).
//!
//! Parses the artifact manifest emitted by `python/compile/aot.py`.
//! Supports the full JSON value grammar (objects, arrays, strings with
//! escapes, numbers, booleans, null); no serialization beyond what the
//! tests need. Errors carry byte offsets for debuggability.

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Object member lookup that errors usefully.
    pub fn expect(&self, key: &str) -> Result<&Json, JsonError> {
        self.get(key).ok_or_else(|| JsonError {
            msg: format!("missing key {key:?}"),
            offset: 0,
        })
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().filter(|n| *n >= 0.0 && n.fract() == 0.0).map(|n| n as u64)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Flatten an array of numbers.
    pub fn as_f64_vec(&self) -> Option<Vec<f64>> {
        self.as_arr()?.iter().map(Json::as_f64).collect()
    }
}

/// Parse error with byte offset.
#[derive(Debug, Clone, PartialEq)]
pub struct JsonError {
    pub msg: String,
    pub offset: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.offset, self.msg)
    }
}

impl std::error::Error for JsonError {}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { msg: msg.to_string(), offset: self.i }
    }

    fn ws(&mut self) {
        while self.i < self.b.len() && self.b[self.i].is_ascii_whitespace() {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected {:?}", c as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected literal {s}")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek().ok_or_else(|| self.err("unexpected end"))? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            b'-' | b'0'..=b'9' => self.number(),
            c => Err(self.err(&format!("unexpected {:?}", c as char))),
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.eat(b'[')?;
        let mut v = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.ws();
            v.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            let c = self.peek().ok_or_else(|| self.err("unterminated string"))?;
            self.i += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek().ok_or_else(|| self.err("bad escape"))?;
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'n' => s.push('\n'),
                        b't' => s.push('\t'),
                        b'r' => s.push('\r'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                return Err(self.err("short \\u escape"));
                            }
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])
                                .map_err(|_| self.err("bad \\u escape"))?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            self.i += 4;
                            s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                        }
                        _ => return Err(self.err("unknown escape")),
                    }
                }
                _ => {
                    // Copy UTF-8 bytes verbatim.
                    s.push(c as char);
                    if c >= 0x80 {
                        // Multi-byte: back up and copy the whole char.
                        s.pop();
                        let start = self.i - 1;
                        let text = std::str::from_utf8(&self.b[start..])
                            .map_err(|_| self.err("invalid utf-8"))?;
                        let ch = text.chars().next().unwrap();
                        s.push(ch);
                        self.i = start + ch.len_utf8();
                    }
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(b'0'..=b'9')) {
            self.i += 1;
        }
        if self.peek() == Some(b'.') {
            self.i += 1;
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.i += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.i += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.i += 1;
            }
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.i += 1;
            }
        }
        let text = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse(r#""hi\n""#).unwrap(), Json::Str("hi\n".into()));
    }

    #[test]
    fn parses_nested_structure() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": "c"}], "d": {}}"#).unwrap();
        let arr = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr[0].as_f64(), Some(1.0));
        assert_eq!(arr[2].get("b").unwrap().as_str(), Some("c"));
        assert!(v.get("d").unwrap().as_obj().unwrap().is_empty());
    }

    #[test]
    fn accessors() {
        let v = Json::parse(r#"{"n": 42, "xs": [1.5, 2.5]}"#).unwrap();
        assert_eq!(v.get("n").unwrap().as_u64(), Some(42));
        assert_eq!(v.get("xs").unwrap().as_f64_vec(), Some(vec![1.5, 2.5]));
        assert!(v.get("missing").is_none());
        assert!(v.expect("missing").is_err());
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("").is_err());
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse(r#"{"a" 1}"#).is_err());
        assert!(Json::parse(r#""unterminated"#).is_err());
    }

    #[test]
    fn unicode_escapes_and_utf8() {
        assert_eq!(
            Json::parse(r#""é""#).unwrap(),
            Json::Str("\u{e9}".into())
        );
        assert_eq!(Json::parse("\"caf\u{e9}\"").unwrap(), Json::Str("café".into()));
    }

    #[test]
    fn parses_real_manifest() {
        // A trimmed copy of the aot.py output shape.
        let text = r#"{
          "config": {"frame": 512, "wavelength": 0.172979},
          "gvectors": [[1.0, 1.0, 1.0], [-1.0, 1.0, 1.0]],
          "entry_points": {
            "fit_orientation": {
              "file": "fit_orientation.hlo.txt",
              "inputs": [{"shape": [256, 3], "dtype": "float32"}],
              "outputs": [{"shape": [256], "dtype": "float32"}]
            }
          }
        }"#;
        let v = Json::parse(text).unwrap();
        assert_eq!(v.get("config").unwrap().get("frame").unwrap().as_u64(), Some(512));
        let ep = v.get("entry_points").unwrap().get("fit_orientation").unwrap();
        assert_eq!(ep.get("file").unwrap().as_str(), Some("fit_orientation.hlo.txt"));
        let shape = ep.get("inputs").unwrap().as_arr().unwrap()[0]
            .get("shape").unwrap().as_f64_vec().unwrap();
        assert_eq!(shape, vec![256.0, 3.0]);
    }
}
