//! Minimal CLI argument parser (offline build: no `clap`).
//!
//! Supports the `xstage <subcommand> --flag value --switch` shape the
//! experiment drivers use. Unknown flags are errors; every flag has a
//! typed accessor with a default.

use std::collections::BTreeMap;

use anyhow::{bail, Result};

/// Parsed command line: a subcommand plus `--key value` / `--switch`
/// flags and positional arguments.
#[derive(Clone, Debug, Default)]
pub struct Args {
    pub command: Option<String>,
    flags: BTreeMap<String, String>,
    switches: Vec<String>,
    pub positional: Vec<String>,
}

impl Args {
    /// Parse from an iterator of arguments (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(argv: I) -> Result<Args> {
        let mut out = Args::default();
        let mut it = argv.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(name) = a.strip_prefix("--") {
                if name.is_empty() {
                    bail!("bare '--' not supported");
                }
                if let Some((k, v)) = name.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else if it.peek().map_or(false, |n| !n.starts_with("--")) {
                    out.flags.insert(name.to_string(), it.next().unwrap());
                } else {
                    out.switches.push(name.to_string());
                }
            } else if out.command.is_none() {
                out.command = Some(a);
            } else {
                out.positional.push(a);
            }
        }
        Ok(out)
    }

    pub fn from_env() -> Result<Args> {
        Self::parse(std::env::args().skip(1))
    }

    pub fn flag(&self, name: &str) -> Option<&str> {
        self.flags.get(name).map(String::as_str)
    }

    pub fn has(&self, name: &str) -> bool {
        self.switches.iter().any(|s| s == name) || self.flags.contains_key(name)
    }

    pub fn u64_or(&self, name: &str, default: u64) -> Result<u64> {
        match self.flag(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| anyhow::anyhow!("--{name}: bad integer {v:?}")),
        }
    }

    pub fn u32_or(&self, name: &str, default: u32) -> Result<u32> {
        Ok(self.u64_or(name, default as u64)? as u32)
    }

    pub fn f64_or(&self, name: &str, default: f64) -> Result<f64> {
        match self.flag(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| anyhow::anyhow!("--{name}: bad float {v:?}")),
        }
    }

    pub fn str_or(&self, name: &str, default: &str) -> String {
        self.flag(name).unwrap_or(default).to_string()
    }

    /// Comma-separated integer list, e.g. `--nodes 512,1024,8192`.
    pub fn u32_list_or(&self, name: &str, default: &[u32]) -> Result<Vec<u32>> {
        match self.flag(name) {
            None => Ok(default.to_vec()),
            Some(v) => v
                .split(',')
                .map(|s| {
                    s.trim()
                        .parse()
                        .map_err(|_| anyhow::anyhow!("--{name}: bad integer {s:?}"))
                })
                .collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(str::to_string)).unwrap()
    }

    #[test]
    fn subcommand_and_flags() {
        let a = parse("fig11 --nodes 8192 --verbose --out=path.txt extra");
        assert_eq!(a.command.as_deref(), Some("fig11"));
        assert_eq!(a.flag("nodes"), Some("8192"));
        assert!(a.has("verbose"));
        assert_eq!(a.flag("out"), Some("path.txt"));
        assert_eq!(a.positional, vec!["extra"]);
    }

    #[test]
    fn typed_accessors() {
        let a = parse("x --n 42 --f 2.5 --list 1,2,3");
        assert_eq!(a.u64_or("n", 0).unwrap(), 42);
        assert_eq!(a.u64_or("missing", 7).unwrap(), 7);
        assert_eq!(a.f64_or("f", 0.0).unwrap(), 2.5);
        assert_eq!(a.u32_list_or("list", &[]).unwrap(), vec![1, 2, 3]);
        assert_eq!(a.u32_list_or("nope", &[9]).unwrap(), vec![9]);
        assert_eq!(a.str_or("s", "d"), "d");
    }

    #[test]
    fn bad_values_error() {
        let a = parse("x --n abc");
        assert!(a.u64_or("n", 0).is_err());
        assert!(a.u32_list_or("n", &[]).is_err());
    }

    #[test]
    fn switch_before_flag() {
        let a = parse("x --dry-run --nodes 4");
        assert!(a.has("dry-run"));
        assert_eq!(a.u32_or("nodes", 0).unwrap(), 4);
    }
}
