//! Tiny benchmark harness (offline build: no `criterion`).
//!
//! Benches are `harness = false` binaries that call [`bench`] /
//! [`bench_n`] and print a stable, grep-friendly report:
//!
//! ```text
//! bench fig10/nodes=8192 ........ median 1.23 ms  (p10 1.20, p90 1.31, n=40)
//! ```
//!
//! Wall-clock benches of *simulations* measure host time to run the
//! virtual experiment; the virtual results themselves are printed by
//! the experiment drivers as paper-vs-measured tables.
//!
//! Two environment knobs:
//!
//! - `XSTAGE_BENCH_JSON=<path>`: append one machine-readable JSON line
//!   per measurement (`{"name":…,"iters":…,"ns_per_iter":…,…}`), so CI
//!   and future PRs can accumulate `BENCH_*.json` trajectory points
//!   without scraping the human report.
//! - `XSTAGE_BENCH_SMOKE=1`: shrink the iteration budget to a fast
//!   correctness pass (CI smoke runs every bench binary this way).

use std::io::Write as _;
use std::time::Instant;

/// One benchmark measurement.
#[derive(Clone, Copy, Debug)]
pub struct Sample {
    pub median: f64,
    pub p10: f64,
    pub p90: f64,
    pub n: usize,
}

/// True when `XSTAGE_BENCH_SMOKE` is set: benches run a minimal
/// iteration budget (CI smoke mode).
pub fn smoke() -> bool {
    std::env::var_os("XSTAGE_BENCH_SMOKE").is_some()
}

/// Run `f` repeatedly for at least `min_runs` iterations and ~0.5 s
/// (one warmup + one timed run in smoke mode), report
/// median/percentiles of per-iteration seconds.
pub fn bench_n<F: FnMut()>(name: &str, min_runs: usize, mut f: F) -> Sample {
    // Warmup.
    f();
    let (min_runs, budget) = if smoke() {
        (1, std::time::Duration::from_millis(1))
    } else {
        (min_runs, std::time::Duration::from_millis(500))
    };
    let mut times = Vec::new();
    let start = Instant::now();
    while times.len() < min_runs || (start.elapsed() < budget && times.len() < 1000) {
        let t0 = Instant::now();
        f();
        times.push(t0.elapsed().as_secs_f64());
    }
    times.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let pick = |q: f64| times[((times.len() - 1) as f64 * q) as usize];
    let s = Sample {
        median: pick(0.5),
        p10: pick(0.1),
        p90: pick(0.9),
        n: times.len(),
    };
    println!(
        "bench {name} ... median {}  (p10 {}, p90 {}, n={})",
        fmt_secs(s.median),
        fmt_secs(s.p10),
        fmt_secs(s.p90),
        s.n
    );
    emit_json(name, &s);
    s
}

/// [`bench_n`] with the default 10 iterations minimum.
pub fn bench<F: FnMut()>(name: &str, f: F) -> Sample {
    bench_n(name, 10, f)
}

/// Report one already-measured wall time in the standard shape (for
/// whole-run measurements too expensive to repeat under [`bench_n`]'s
/// iteration loop — the `scale` matrix points run once per mode).
pub fn record(name: &str, secs: f64) -> Sample {
    let s = Sample { median: secs, p10: secs, p90: secs, n: 1 };
    println!(
        "bench {name} ... median {}  (p10 {}, p90 {}, n={})",
        fmt_secs(s.median),
        fmt_secs(s.p10),
        fmt_secs(s.p90),
        s.n
    );
    emit_json(name, &s);
    s
}

/// One measurement as a JSON object line (stable key order).
pub fn json_line(name: &str, s: &Sample) -> String {
    format!(
        "{{\"name\":\"{}\",\"iters\":{},\"ns_per_iter\":{:.1},\"p10_ns\":{:.1},\"p90_ns\":{:.1}}}",
        escape_json(name),
        s.n,
        s.median * 1e9,
        s.p10 * 1e9,
        s.p90 * 1e9,
    )
}

fn escape_json(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

/// Append the JSON line to `$XSTAGE_BENCH_JSON`, if set. Errors are
/// reported to stderr, never fatal to the bench.
fn emit_json(name: &str, s: &Sample) {
    let Some(path) = std::env::var_os("XSTAGE_BENCH_JSON") else { return };
    let line = json_line(name, s);
    let res = std::fs::OpenOptions::new()
        .create(true)
        .append(true)
        .open(&path)
        .and_then(|mut f| writeln!(f, "{line}"));
    if let Err(e) = res {
        eprintln!("warning: XSTAGE_BENCH_JSON append failed: {e}");
    }
}

/// Report a resident-state measurement in the same grep-friendly shape
/// as [`bench`], and append a distinct JSON line
/// (`{"name":…,"state_bytes":…,"units":…,"bytes_per_unit":…}`) to
/// `$XSTAGE_BENCH_JSON` so footprint trajectories accumulate alongside
/// timing ones.
pub fn report_state(name: &str, sb: crate::units::StateBytes) {
    println!("state {name} ... {sb}");
    let Some(path) = std::env::var_os("XSTAGE_BENCH_JSON") else { return };
    let line = state_json_line(name, sb);
    let res = std::fs::OpenOptions::new()
        .create(true)
        .append(true)
        .open(&path)
        .and_then(|mut f| writeln!(f, "{line}"));
    if let Err(e) = res {
        eprintln!("warning: XSTAGE_BENCH_JSON append failed: {e}");
    }
}

/// Report a plain counter (kernel occupancy peaks, stale-check
/// economy) in the grep-friendly shape and as a distinct JSON line
/// (`{"name":…,"counter":…}`) appended to `$XSTAGE_BENCH_JSON`, so
/// kernel-observability trajectories accumulate alongside timing and
/// footprint ones.
pub fn report_counter(name: &str, value: u64) {
    println!("counter {name} ... {value}");
    let Some(path) = std::env::var_os("XSTAGE_BENCH_JSON") else { return };
    let line = counter_json_line(name, value);
    let res = std::fs::OpenOptions::new()
        .create(true)
        .append(true)
        .open(&path)
        .and_then(|mut f| writeln!(f, "{line}"));
    if let Err(e) = res {
        eprintln!("warning: XSTAGE_BENCH_JSON append failed: {e}");
    }
}

/// One counter as a JSON object line (stable key order).
pub fn counter_json_line(name: &str, value: u64) -> String {
    format!("{{\"name\":\"{}\",\"counter\":{}}}", escape_json(name), value)
}

/// One state measurement as a JSON object line (stable key order).
pub fn state_json_line(name: &str, sb: crate::units::StateBytes) -> String {
    format!(
        "{{\"name\":\"{}\",\"state_bytes\":{},\"units\":{},\"bytes_per_unit\":{}}}",
        escape_json(name),
        sb.total,
        sb.units,
        sb.per_unit(),
    )
}

/// Human duration (s/ms/us/ns).
pub fn fmt_secs(s: f64) -> String {
    if s >= 1.0 {
        format!("{s:.3} s")
    } else if s >= 1e-3 {
        format!("{:.3} ms", s * 1e3)
    } else if s >= 1e-6 {
        format!("{:.3} us", s * 1e6)
    } else {
        format!("{:.0} ns", s * 1e9)
    }
}

/// Section header for bench output.
pub fn section(title: &str) {
    println!("\n### {title}");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_returns_sane_sample() {
        let s = bench_n("test/noop", 5, || {
            std::hint::black_box(1 + 1);
        });
        assert!(s.n >= 1);
        assert!(s.median >= 0.0 && s.p10 <= s.p90);
    }

    #[test]
    fn fmt_covers_ranges() {
        assert_eq!(fmt_secs(2.5), "2.500 s");
        assert_eq!(fmt_secs(0.0025), "2.500 ms");
        assert_eq!(fmt_secs(2.5e-6), "2.500 us");
        assert_eq!(fmt_secs(3.1e-9), "3 ns");
    }

    #[test]
    fn json_line_is_parseable() {
        let s = Sample { median: 1.5e-6, p10: 1.0e-6, p90: 2.0e-6, n: 42 };
        let line = json_line("flownet/churn-64", &s);
        assert_eq!(
            line,
            "{\"name\":\"flownet/churn-64\",\"iters\":42,\
             \"ns_per_iter\":1500.0,\"p10_ns\":1000.0,\"p90_ns\":2000.0}"
        );
        // Round-trips through the in-tree JSON parser.
        let v = crate::util::json::Json::parse(&line).unwrap();
        assert_eq!(v.get("iters").and_then(|j| j.as_f64()), Some(42.0));
    }

    #[test]
    fn state_json_line_is_parseable() {
        let sb = crate::units::StateBytes::new(4096, 16);
        let line = state_json_line("sched/sessions", sb);
        assert_eq!(
            line,
            "{\"name\":\"sched/sessions\",\"state_bytes\":4096,\
             \"units\":16,\"bytes_per_unit\":256}"
        );
        let v = crate::util::json::Json::parse(&line).unwrap();
        assert_eq!(v.get("bytes_per_unit").and_then(|j| j.as_f64()), Some(256.0));
        // Zero units never divides by zero.
        assert_eq!(crate::units::StateBytes::new(100, 0).per_unit(), 0);
    }

    #[test]
    fn counter_json_line_is_parseable() {
        let line = counter_json_line("kernel/stale_pops", 1234);
        assert_eq!(line, "{\"name\":\"kernel/stale_pops\",\"counter\":1234}");
        let v = crate::util::json::Json::parse(&line).unwrap();
        assert_eq!(v.get("counter").and_then(|j| j.as_f64()), Some(1234.0));
    }

    #[test]
    fn json_escapes_quotes_and_controls() {
        let s = Sample { median: 0.0, p10: 0.0, p90: 0.0, n: 1 };
        let line = json_line("we\"ird\\name\n", &s);
        assert!(line.contains("we\\\"ird\\\\name\\u000a"));
    }
}
