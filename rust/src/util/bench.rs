//! Tiny benchmark harness (offline build: no `criterion`).
//!
//! Benches are `harness = false` binaries that call [`bench`] /
//! [`bench_n`] and print a stable, grep-friendly report:
//!
//! ```text
//! bench fig10/nodes=8192 ........ median 1.23 ms  (p10 1.20, p90 1.31, n=40)
//! ```
//!
//! Wall-clock benches of *simulations* measure host time to run the
//! virtual experiment; the virtual results themselves are printed by
//! the experiment drivers as paper-vs-measured tables.

use std::time::Instant;

/// One benchmark measurement.
#[derive(Clone, Copy, Debug)]
pub struct Sample {
    pub median: f64,
    pub p10: f64,
    pub p90: f64,
    pub n: usize,
}

/// Run `f` repeatedly for at least `min_runs` iterations and ~0.5 s,
/// report median/percentiles of per-iteration seconds.
pub fn bench_n<F: FnMut()>(name: &str, min_runs: usize, mut f: F) -> Sample {
    // Warmup.
    f();
    let mut times = Vec::new();
    let budget = std::time::Duration::from_millis(500);
    let start = Instant::now();
    while times.len() < min_runs || (start.elapsed() < budget && times.len() < 1000) {
        let t0 = Instant::now();
        f();
        times.push(t0.elapsed().as_secs_f64());
    }
    times.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let pick = |q: f64| times[((times.len() - 1) as f64 * q) as usize];
    let s = Sample {
        median: pick(0.5),
        p10: pick(0.1),
        p90: pick(0.9),
        n: times.len(),
    };
    println!(
        "bench {name} ... median {}  (p10 {}, p90 {}, n={})",
        fmt_secs(s.median),
        fmt_secs(s.p10),
        fmt_secs(s.p90),
        s.n
    );
    s
}

/// [`bench_n`] with the default 10 iterations minimum.
pub fn bench<F: FnMut()>(name: &str, f: F) -> Sample {
    bench_n(name, 10, f)
}

/// Human duration (s/ms/us/ns).
pub fn fmt_secs(s: f64) -> String {
    if s >= 1.0 {
        format!("{s:.3} s")
    } else if s >= 1e-3 {
        format!("{:.3} ms", s * 1e3)
    } else if s >= 1e-6 {
        format!("{:.3} us", s * 1e6)
    } else {
        format!("{:.0} ns", s * 1e9)
    }
}

/// Section header for bench output.
pub fn section(title: &str) {
    println!("\n### {title}");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_returns_sane_sample() {
        let s = bench_n("test/noop", 5, || {
            std::hint::black_box(1 + 1);
        });
        assert!(s.n >= 5);
        assert!(s.median >= 0.0 && s.p10 <= s.p90);
    }

    #[test]
    fn fmt_covers_ranges() {
        assert_eq!(fmt_secs(2.5), "2.500 s");
        assert_eq!(fmt_secs(0.0025), "2.500 ms");
        assert_eq!(fmt_secs(2.5e-6), "2.500 us");
        assert_eq!(fmt_secs(3.1e-9), "3 ns");
    }
}
