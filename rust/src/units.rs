//! Simulation units: virtual time, byte counts, bandwidths.
//!
//! Virtual time is a `u64` nanosecond counter ([`SimTime`]) so event
//! ordering is exact and runs are bit-reproducible; bandwidth math is
//! done in `f64` and rounded *up* to the next nanosecond when durations
//! are materialised (a transfer never finishes early).

use std::fmt;
use std::ops::{Add, AddAssign, Sub};

/// Virtual simulation time in nanoseconds since run start.
#[derive(Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash, Default, Debug)]
pub struct SimTime(pub u64);

/// A span of virtual time in nanoseconds.
#[derive(Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash, Default, Debug)]
pub struct Duration(pub u64);

impl SimTime {
    pub const ZERO: SimTime = SimTime(0);

    pub fn secs_f64(self) -> f64 {
        self.0 as f64 / 1e9
    }
}

impl Duration {
    pub const ZERO: Duration = Duration(0);

    pub fn from_secs_f64(s: f64) -> Duration {
        assert!(s >= 0.0 && s.is_finite(), "bad duration {s}");
        Duration((s * 1e9).ceil() as u64)
    }

    pub fn from_micros(us: u64) -> Duration {
        Duration(us * 1_000)
    }

    pub fn from_millis(ms: u64) -> Duration {
        Duration(ms * 1_000_000)
    }

    pub fn from_secs(s: u64) -> Duration {
        Duration(s * 1_000_000_000)
    }

    pub fn secs_f64(self) -> f64 {
        self.0 as f64 / 1e9
    }
}

impl Add<Duration> for SimTime {
    type Output = SimTime;
    fn add(self, d: Duration) -> SimTime {
        SimTime(self.0 + d.0)
    }
}

impl AddAssign<Duration> for SimTime {
    fn add_assign(&mut self, d: Duration) {
        self.0 += d.0;
    }
}

impl Sub<SimTime> for SimTime {
    type Output = Duration;
    fn sub(self, rhs: SimTime) -> Duration {
        Duration(self.0.saturating_sub(rhs.0))
    }
}

impl Add<Duration> for Duration {
    type Output = Duration;
    fn add(self, d: Duration) -> Duration {
        Duration(self.0 + d.0)
    }
}

impl AddAssign<Duration> for Duration {
    fn add_assign(&mut self, d: Duration) {
        self.0 += d.0;
    }
}

impl fmt::Display for SimTime {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{:.3}s", self.secs_f64())
    }
}

impl fmt::Display for Duration {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{:.3}s", self.secs_f64())
    }
}

/// Byte-count helpers (binary prefixes for capacities, decimal GB/s for
/// bandwidth, matching the paper's conventions).
pub const KIB: u64 = 1 << 10;
pub const MIB: u64 = 1 << 20;
pub const GIB: u64 = 1 << 30;
pub const MB: u64 = 1_000_000;
pub const GB: u64 = 1_000_000_000;
pub const TB: u64 = 1_000_000_000_000;

/// Time to move `bytes` at `bw` bytes/second, rounded up to the ns.
pub fn transfer_time(bytes: u64, bw: f64) -> Duration {
    assert!(bw > 0.0, "non-positive bandwidth");
    Duration::from_secs_f64(bytes as f64 / bw)
}

/// Pretty-print a byte count ("577.0 MB", "1.5 GiB"-free: decimal units).
pub fn fmt_bytes(b: u64) -> String {
    if b >= GB {
        format!("{:.2} GB", b as f64 / GB as f64)
    } else if b >= MB {
        format!("{:.1} MB", b as f64 / MB as f64)
    } else if b >= 1000 {
        format!("{:.1} KB", b as f64 / 1000.0)
    } else {
        format!("{b} B")
    }
}

/// A resident-state measurement: `total` bookkeeping bytes spread over
/// `units` accountable things (sessions, paths, nodes...). The scale
/// harness reports these so footprint-per-session / per-path growth is
/// a tracked number, not a hope.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub struct StateBytes {
    pub total: u64,
    pub units: u64,
}

impl StateBytes {
    pub fn new(total: u64, units: u64) -> StateBytes {
        StateBytes { total, units }
    }

    /// Bytes per accountable unit (0 when there are no units).
    pub fn per_unit(&self) -> u64 {
        if self.units == 0 {
            0
        } else {
            self.total / self.units
        }
    }
}

impl fmt::Display for StateBytes {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "{} over {} units ({}/unit)",
            fmt_bytes(self.total),
            self.units,
            fmt_bytes(self.per_unit())
        )
    }
}

/// Pretty-print a bandwidth in GB/s (paper convention).
pub fn fmt_bw(bytes_per_sec: f64) -> String {
    if bytes_per_sec >= GB as f64 {
        format!("{:.1} GB/s", bytes_per_sec / GB as f64)
    } else {
        format!("{:.1} MB/s", bytes_per_sec / MB as f64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn time_arithmetic() {
        let t = SimTime::ZERO + Duration::from_secs(2) + Duration::from_millis(500);
        assert_eq!(t.0, 2_500_000_000);
        assert_eq!((t - SimTime(500_000_000)).secs_f64(), 2.0);
    }

    #[test]
    fn transfer_rounds_up() {
        // 1 byte at 3 B/s = 333_333_333.33 ns -> must round UP.
        let d = transfer_time(1, 3.0);
        assert_eq!(d.0, 333_333_334);
    }

    #[test]
    fn transfer_simple() {
        assert_eq!(transfer_time(GB, GB as f64), Duration::from_secs(1));
        assert_eq!(transfer_time(0, 1.0), Duration::ZERO);
    }

    #[test]
    #[should_panic(expected = "non-positive bandwidth")]
    fn transfer_zero_bw_panics() {
        transfer_time(1, 0.0);
    }

    #[test]
    fn formatting() {
        assert_eq!(fmt_bytes(577 * MB), "577.0 MB");
        assert_eq!(fmt_bytes(2 * GB), "2.00 GB");
        assert_eq!(fmt_bytes(512), "512 B");
        assert_eq!(fmt_bw(240.0 * GB as f64), "240.0 GB/s");
        assert_eq!(fmt_bw(53.4 * MB as f64), "53.4 MB/s");
    }

    #[test]
    fn duration_display() {
        assert_eq!(format!("{}", Duration::from_millis(10_800)), "10.800s");
    }
}
