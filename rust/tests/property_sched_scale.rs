//! Property suite for the fleet-scale hot-path flattening.
//!
//! Two differential families, 500 random schedules each:
//!
//! - **Fair pick**: every schedule runs under the seed's linear scan
//!   ([`FairPick::Scan`], string-keyed storage) and the flattened
//!   implementations ([`FairPick::Indexed`], interned ids); virtual
//!   clock, per-task completion times, and byte accounting must be
//!   bit-identical. These are debug builds, so the scheduler's
//!   in-code `debug_assert` additionally cross-checks the indexed
//!   pick against the scan on **every single dispatch decision** —
//!   the suite exercises decision-for-decision equivalence, not just
//!   end states.
//! - **Interned storage surface**: two [`NodeStores`] under tight
//!   RAM/SSD budgets are driven in lockstep through the same random
//!   write/touch/promote/evict/pin schedule — one via the string API,
//!   one via the pre-interned id API. After every step, both tiers'
//!   snapshots, coverage answers, reads, and the path↔id bijection
//!   must agree exactly (including LRU/demotion behaviour, which
//!   would expose any clock or victim-order skew between the two
//!   surfaces).

use xstage::cluster::{orthros, Topology};
use xstage::dataflow::sched::{SessionId, SessionScheduler, SessionStats};
use xstage::dataflow::{FairPick, SchedulerCfg, Task, TaskGraph};
use xstage::engine::SimCore;
use xstage::mpisim::Comm;
use xstage::pfs::{Blob, GpfsParams};
use xstage::storage::{NodeStores, PromoteOutcome, StorageTier, StoreWrite};
use xstage::units::{Duration, SimTime, MB};
use xstage::util::prng::Pcg64;

/// Schedule count: `XSTAGE_PROP_SCHEDULES` if set, else 500.
fn schedules() -> u64 {
    xstage::util::prop_schedules(500)
}

// ---------------------------------------------------------------------
// Family 1: indexed fair pick == linear scan, schedule for schedule
// ---------------------------------------------------------------------

const PATHS: &[&str] = &["/tmp/s0.bin", "/tmp/s1.bin", "/pfs/u0.bin", "/pfs/u1.bin"];

/// A random multi-session workload: a few sessions of small graphs
/// with random chains/inputs on a machine small enough that sessions
/// genuinely contend for slots.
struct Scenario {
    nodes: u32,
    ranks: u32,
    cache_inputs: bool,
    locality_aware: bool,
    graphs: Vec<TaskGraph>,
}

fn scenario(seed: u64) -> Scenario {
    let mut rng = Pcg64::new(seed);
    let sessions = rng.range_u64(2, 10) as usize;
    let mut graphs = Vec::with_capacity(sessions);
    for s in 0..sessions {
        let mut g = TaskGraph::new();
        let n = rng.range_u64(2, 8) as usize;
        for t in 0..n {
            let mut task = Task::compute(
                format!("s{s}/t{t}"),
                Duration::from_secs_f64(rng.log_uniform(0.5, 10.0)),
            );
            if t > 0 && rng.f64() < 0.4 {
                let dep = rng.range_u64(0, t as u64 - 1) as usize;
                task = task.with_dep(xstage::dataflow::TaskId(dep));
            }
            if rng.f64() < 0.6 {
                let p = PATHS[rng.range_u64(0, PATHS.len() as u64 - 1) as usize];
                task = task.with_input(p, None);
            }
            if rng.f64() < 0.3 {
                task = task.with_output(MB / 4);
            }
            g.add(task);
        }
        graphs.push(g);
    }
    Scenario {
        nodes: rng.range_u64(1, 3) as u32,
        ranks: rng.range_u64(2, 4) as u32,
        cache_inputs: rng.f64() < 0.5,
        locality_aware: rng.f64() < 0.5,
        graphs,
    }
}

fn run_scenario(
    sc: &Scenario,
    fair_pick: FairPick,
    interned: bool,
) -> (SimTime, Vec<SessionStats>) {
    let mut core = SimCore::new();
    let mut spec = orthros();
    spec.nodes = sc.nodes;
    spec.ranks_per_node = sc.ranks;
    let topo = Topology::build(spec, GpfsParams::default(), &mut core.net);
    let comm = Comm::world(&topo.spec);
    // Two paths staged on a node prefix, two only on the shared FS.
    for p in PATHS {
        core.pfs.write(*p, Blob::synthetic(2 * MB, 0xF00D));
    }
    core.node_write_range(0, 0, "/tmp/s0.bin", Blob::synthetic(2 * MB, 0xF00D));
    core.node_write_range(0, sc.nodes - 1, "/tmp/s1.bin", Blob::synthetic(2 * MB, 0xF00D));
    let cfg = SchedulerCfg {
        cache_inputs: sc.cache_inputs,
        locality_aware: sc.locality_aware,
        fair_pick,
        interned_paths: interned,
        ..Default::default()
    };
    let mut ss = SessionScheduler::new(topo, comm, cfg);
    let sids: Vec<SessionId> =
        sc.graphs.iter().map(|g| ss.add_session(&mut core, g.clone())).collect();
    core.run(&mut ss);
    assert!(ss.all_done());
    (core.now, sids.into_iter().map(|s| ss.stats(s)).collect())
}

#[test]
fn indexed_fair_pick_matches_scan_on_500_random_schedules() {
    for seed in 0..schedules() {
        let sc = scenario(seed);
        let (now_scan, scan) = run_scenario(&sc, FairPick::Scan, false);
        let (now_idx, idx) = run_scenario(&sc, FairPick::Indexed, true);
        assert_eq!(now_scan, now_idx, "virtual clock diverged (seed {seed})");
        assert_eq!(scan.len(), idx.len());
        for (i, (a, b)) in scan.iter().zip(&idx).enumerate() {
            assert_eq!(a.completion, b.completion, "completions (seed {seed}, session {i})");
            assert_eq!(a.finished, b.finished, "finish time (seed {seed}, session {i})");
            assert_eq!(a.reads, b.reads, "read accounting (seed {seed}, session {i})");
        }
    }
}

// ---------------------------------------------------------------------
// Family 2: string-keyed and id-keyed storage surfaces in lockstep
// ---------------------------------------------------------------------

const NODES: u32 = 8;
const POOL: &[&str] = &[
    "/projects/a.bin",
    "/projects/b.bin",
    "/projects/c.bin",
    "/projects/d.bin",
    "/projects/e.bin",
    "/projects/f.bin",
];

fn stored(w: &StoreWrite) -> bool {
    matches!(w, StoreWrite::Stored { .. })
}

/// Full cross-surface state check: bijection, coverage, reads, and
/// both tiers' snapshots.
fn assert_surfaces_agree(a: &NodeStores, b: &NodeStores, rng: &mut Pcg64, step: usize) {
    assert_eq!(a.dump(), b.dump(), "RAM snapshots diverged at step {step}");
    assert_eq!(
        a.dump_tier(StorageTier::Ssd),
        b.dump_tier(StorageTier::Ssd),
        "SSD snapshots diverged at step {step}"
    );
    for p in POOL {
        assert_eq!(a.path_id(p), b.path_id(p), "interning diverged for {p} at step {step}");
        let Some(id) = a.path_id(p) else { continue };
        assert_eq!(a.resolve_path(id), *p);
        assert_eq!(b.resolve_path(id), *p);
        // String answers on A == id answers on B, both directions.
        assert_eq!(a.coverage_of(p), b.coverage_of_id(id), "{p} step {step}");
        assert_eq!(a.coverage_of_id(id), b.coverage_of(p), "{p} step {step}");
        assert_eq!(
            a.coverage_of_tier(StorageTier::Ssd, p),
            b.coverage_of_tier_id(StorageTier::Ssd, id),
            "{p} step {step}"
        );
        let n = rng.range_u64(0, NODES as u64 - 1) as u32;
        assert_eq!(
            a.read(n, p).map(Blob::len),
            b.read_id(n, id).map(Blob::len),
            "{p} node {n} step {step}"
        );
        assert_eq!(
            a.read_tier(StorageTier::Ssd, n, p).map(Blob::len),
            b.read_tier_id(StorageTier::Ssd, n, id).map(Blob::len),
            "{p} node {n} step {step}"
        );
    }
}

#[test]
fn interned_storage_surface_answers_identically_on_500_random_schedules() {
    for seed in 0..schedules() {
        let mut rng = Pcg64::new(0x1D5EED ^ seed);
        let mut qrng = Pcg64::new(0xC0FFEE ^ seed);
        let mut a = NodeStores::new(); // driven via the string surface
        let mut b = NodeStores::new(); // driven via the id surface
        for s in [&mut a, &mut b] {
            s.set_capacity(Some(3 * MB));
            s.set_ssd_capacity(Some(4 * MB));
        }
        for step in 0..40 {
            let p = POOL[rng.range_u64(0, POOL.len() as u64 - 1) as usize];
            let lo = rng.range_u64(0, NODES as u64 - 1) as u32;
            let hi = rng.range_u64(lo as u64, NODES as u64 - 1) as u32;
            match rng.range_u64(0, 9) {
                0..=3 => {
                    let len = rng.range_u64(100_000, 1_200_000);
                    let bseed = rng.next_u64();
                    let ra = a.write_range_evicting(lo, hi, p, Blob::synthetic(len, bseed));
                    let id = b.intern_path(p);
                    let rb = b.write_range_evicting_id(lo, hi, id, Blob::synthetic(len, bseed));
                    assert_eq!(stored(&ra), stored(&rb), "write outcome (seed {seed} step {step})");
                }
                4..=5 => {
                    // Touches must advance both clocks identically, so
                    // only touch paths both sides have interned.
                    if a.path_id(p).is_some() {
                        let tier =
                            if rng.f64() < 0.5 { StorageTier::Ram } else { StorageTier::Ssd };
                        a.touch_tier(tier, lo, p);
                        let id = b.path_id(p).unwrap();
                        b.touch_tier_id(tier, lo, id);
                    }
                }
                6 => {
                    let ra = a.promote_range(lo, hi, p);
                    let rb = match b.path_id(p) {
                        Some(id) => b.promote_range_id(lo, hi, id),
                        None => PromoteOutcome::Missing,
                    };
                    assert_eq!(
                        matches!(ra, PromoteOutcome::Promoted { .. }),
                        matches!(rb, PromoteOutcome::Promoted { .. }),
                        "promotion outcome (seed {seed} step {step})"
                    );
                }
                7 => {
                    // evict_path has no id variant (teardown path).
                    a.evict_path(p);
                    b.evict_path(p);
                }
                _ => {
                    if rng.f64() < 0.6 {
                        a.pin(p);
                        b.pin(p);
                    } else {
                        a.unpin(p);
                        b.unpin(p);
                    }
                }
            }
            assert_surfaces_agree(&a, &b, &mut qrng, step);
        }
        assert_eq!(a.state_bytes(), b.state_bytes(), "state accounting diverged (seed {seed})");
    }
}
