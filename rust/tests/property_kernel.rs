//! Property suite for the event-core kernel: the bucketed timer wheel
//! vs the seed binary-heap backend, generation-stamped stale-check
//! reclamation, and the parallel experiment-matrix runner.
//!
//! Four families:
//!
//! - **Heap differential** (500 schedules): random push/pop/cancel
//!   interleavings — offsets spanning same-tick ties, the in-window
//!   wheel range, and the far-future overflow heap — driven against
//!   both [`HeapKind`] backends in lockstep. Every pop, peek, length,
//!   and cancel verdict must match exactly, including the `(time,
//!   seq)` FIFO tie-break, and both drains must agree to the end.
//! - **Full-run bit-identity, scale**: a fleet matrix point run under
//!   both backends must finish every session at the same virtual
//!   instant with the same useful event count (raw event counts differ
//!   only by the stale pops the wheel reclaims eagerly).
//! - **Full-run bit-identity, chaos**: same under mid-run component
//!   retirement (node kills) — the nastiest reclamation path.
//! - **Parallel-runner determinism**: every experiment driver's
//!   matrix, run serially and with 4 workers, must produce
//!   byte-identical tables and series (the scale table's host-time
//!   columns excluded — they measure the machine, not the model).

use xstage::experiments::scale::{self, PathMode};
use xstage::experiments::{chaos, elastic, fig10, fig11, fig12, fig13, ingest, serve, tiers};
use xstage::simtime::flownet::ThroughputMode;
use xstage::simtime::heap::{EventHeap, HeapKind};
use xstage::staging::service::run_serve_kernel;
use xstage::units::SimTime;
use xstage::util::prng::Pcg64;

/// Schedule count: `XSTAGE_PROP_SCHEDULES` if set, else 500.
fn schedules() -> u64 {
    xstage::util::prop_schedules(500)
}

// ---------------------------------------------------------------------
// Family 1: wheel vs seed heap under random push/pop/cancel schedules
// ---------------------------------------------------------------------

/// One random offset from the current virtual floor, shaped to hit
/// every wheel regime: exact ties (FIFO tie-break), same-tick
/// neighbours, the in-window range, and the far-future overflow.
fn offset(rng: &mut Pcg64) -> u64 {
    match rng.range_u64(0, 9) {
        0 => 0,                                    // same-instant tie
        1 => rng.range_u64(0, 1 << 10),            // same wheel tick
        2..=6 => rng.range_u64(0, 1 << 34),        // in-window
        7 | 8 => rng.range_u64(1 << 34, 1 << 37),  // window edge / just past
        _ => rng.range_u64(1 << 37, 1 << 40),      // deep overflow
    }
}

#[test]
fn wheel_and_seed_heap_agree_on_random_schedules() {
    for seed in 0..schedules() {
        let mut rng = Pcg64::new(0xFEE1_u64 ^ seed.wrapping_mul(0x9E37_79B9_7F4A_7C15));
        let mut seed_heap: EventHeap<u32> = EventHeap::with_kind(HeapKind::Seed);
        let mut wheel: EventHeap<u32> = EventHeap::with_kind(HeapKind::Wheel);
        // The engine's monotone contract: no push below the last pop.
        let mut floor = SimTime(0);
        // Live entries both heaps hold, as (time, seq, payload).
        let mut live: Vec<(SimTime, u64, u32)> = Vec::new();
        let mut payload = 0u32;
        for _ in 0..rng.range_u64(10, 300) {
            match rng.range_u64(0, 9) {
                // Push (~60%).
                0..=5 => {
                    let t = SimTime(floor.0 + offset(&mut rng));
                    payload += 1;
                    let s0 = seed_heap.push(t, payload);
                    let s1 = wheel.push(t, payload);
                    assert_eq!(s0, s1, "seq counters diverged (schedule {seed})");
                    live.push((t, s0, payload));
                }
                // Pop (~30%).
                6..=8 => {
                    assert_eq!(seed_heap.peek_time(), wheel.peek_time(), "schedule {seed}");
                    let a = seed_heap.pop();
                    let b = wheel.pop();
                    assert_eq!(a, b, "pop diverged (schedule {seed})");
                    if let Some((t, p)) = a {
                        floor = t;
                        let i = live.iter().position(|&(_, _, lp)| lp == p).unwrap();
                        live.swap_remove(i);
                    }
                }
                // Cancel a random live entry (~10%).
                _ => {
                    if live.is_empty() {
                        continue;
                    }
                    let i = rng.range_u64(0, live.len() as u64 - 1) as usize;
                    let (t, s, _) = live.swap_remove(i);
                    let a = seed_heap.cancel(t, s);
                    let b = wheel.cancel(t, s);
                    assert!(a && b, "live cancel missed (schedule {seed})");
                }
            }
            assert_eq!(seed_heap.len(), wheel.len(), "schedule {seed}");
            assert_eq!(seed_heap.is_empty(), wheel.is_empty(), "schedule {seed}");
        }
        // Drain to the end: the full remaining order must match, and
        // both heaps must surface exactly the surviving entries in
        // (time, seq) order.
        let mut drained = 0usize;
        loop {
            let a = seed_heap.pop();
            let b = wheel.pop();
            assert_eq!(a, b, "drain diverged (schedule {seed})");
            match a {
                Some((t, _)) => {
                    assert!(t >= floor, "drain went backwards (schedule {seed})");
                    floor = t;
                    drained += 1;
                }
                None => break,
            }
        }
        assert_eq!(drained, live.len(), "drain count wrong (schedule {seed})");
    }
}

#[test]
fn cancelled_entries_never_pop() {
    // Cancel every pushed entry: both backends must drain empty, and a
    // second cancel of the same handle must miss on both.
    let mut seed_heap: EventHeap<u32> = EventHeap::with_kind(HeapKind::Seed);
    let mut wheel: EventHeap<u32> = EventHeap::with_kind(HeapKind::Wheel);
    let mut rng = Pcg64::new(77);
    let mut handles = Vec::new();
    for p in 0..200u32 {
        let t = SimTime(offset(&mut rng));
        handles.push((t, seed_heap.push(t, p)));
        wheel.push(t, p);
    }
    for &(t, s) in &handles {
        assert!(seed_heap.cancel(t, s));
        assert!(wheel.cancel(t, s));
    }
    for &(t, s) in &handles {
        assert!(!seed_heap.cancel(t, s), "double cancel hit");
        assert!(!wheel.cancel(t, s), "double cancel hit");
    }
    assert_eq!(seed_heap.pop(), None);
    assert_eq!(wheel.pop(), None);
}

// ---------------------------------------------------------------------
// Families 2 + 3: full-run bit-identity across event-heap backends
// ---------------------------------------------------------------------

#[test]
fn scale_point_is_bit_identical_across_backends() {
    for (nodes, sessions, seed) in [(16, 60, 7), (8, 50, 3)] {
        let s = scale::run_point_kernel(nodes, sessions, PathMode::Flat, seed, HeapKind::Seed);
        let w = scale::run_point_kernel(nodes, sessions, PathMode::Flat, seed, HeapKind::Wheel);
        assert_eq!(s.finished, w.finished, "finish times diverged at n{nodes}/s{sessions}");
        assert_eq!(s.useful_events(), w.useful_events(), "useful events diverged");
        // The wheel reclaims eagerly: what the seed pops stale, the
        // wheel either reclaimed or (rarely) popped stale itself.
        assert_eq!(
            w.kernel.stale_checks_reclaimed + w.kernel.stale_check_pops,
            s.kernel.stale_check_pops,
            "stale-check economy out of balance"
        );
        assert_eq!(s.kernel.stale_checks_reclaimed, 0, "seed backend must not reclaim");
    }
}

#[test]
fn chaos_point_is_bit_identical_across_backends() {
    for stealing in [false, true] {
        let cfg = chaos::cfg(3, stealing, 8, 7);
        let s = run_serve_kernel(chaos::NODES, &cfg, ThroughputMode::Fast, HeapKind::Seed);
        let w = run_serve_kernel(chaos::NODES, &cfg, ThroughputMode::Fast, HeapKind::Wheel);
        assert_eq!(s.turnaround_secs, w.turnaround_secs, "stealing {stealing}");
        assert_eq!(s.useful_events(), w.useful_events(), "stealing {stealing}");
        assert_eq!(s.lost_tasks, w.lost_tasks, "stealing {stealing}");
        assert_eq!(s.staged_bytes, w.staged_bytes, "stealing {stealing}");
        assert_eq!(s.copied_bytes, w.copied_bytes, "stealing {stealing}");
    }
}

// ---------------------------------------------------------------------
// Family 4: the parallel matrix runner is worker-count-invisible
// ---------------------------------------------------------------------

/// Assert two experiment results byte-identical, optionally masking
/// table columns (by header index) that measure host time.
fn assert_result_identical(
    name: &str,
    a: &xstage::experiments::ExpResult,
    b: &xstage::experiments::ExpResult,
    host_cols: &[usize],
) {
    assert_eq!(a.series, b.series, "{name}: series diverged across worker counts");
    assert_eq!(a.table.rows.len(), b.table.rows.len(), "{name}: row counts diverged");
    for (ra, rb) in a.table.rows.iter().zip(&b.table.rows) {
        for (ci, (ca, cb)) in ra.iter().zip(rb).enumerate() {
            if host_cols.contains(&ci) {
                continue;
            }
            assert_eq!(ca, cb, "{name}: cell diverged across worker counts");
        }
    }
}

#[test]
fn serve_matrix_is_worker_count_invisible() {
    assert_result_identical(
        "serve",
        &serve::run_with_jobs(3, 9, 1),
        &serve::run_with_jobs(3, 9, 4),
        &[],
    );
}

#[test]
fn tiers_matrix_is_worker_count_invisible() {
    assert_result_identical(
        "tiers",
        &tiers::run_with_jobs(4, 7, 1),
        &tiers::run_with_jobs(4, 7, 4),
        &[],
    );
}

#[test]
fn chaos_matrix_is_worker_count_invisible() {
    assert_result_identical(
        "chaos",
        &chaos::run_with_jobs(6, 9, 1),
        &chaos::run_with_jobs(6, 9, 4),
        &[],
    );
}

#[test]
fn ingest_matrix_is_worker_count_invisible() {
    assert_result_identical(
        "ingest",
        &ingest::run_with_jobs(3, 9, 1),
        &ingest::run_with_jobs(3, 9, 4),
        &[],
    );
}

#[test]
fn elastic_matrix_is_worker_count_invisible() {
    assert_result_identical(
        "elastic",
        &elastic::run_with_jobs(4, 9, 1),
        &elastic::run_with_jobs(4, 9, 4),
        &[],
    );
}

#[test]
fn scale_matrix_is_worker_count_invisible_outside_host_columns() {
    // Scale is the one experiment whose table *and* series carry
    // host-time measurements: columns 2-5 ("seed ev/s", "flat ev/s",
    // "speedup", "ms-host/sim-s") and both series (speedup,
    // events/sec) measure the machine, so only the virtual and
    // resident-state columns must match bitwise.
    let a = scale::run_with_jobs(&[8, 16], &[30, 40], 5, 1);
    let b = scale::run_with_jobs(&[8, 16], &[30, 40], 5, 4);
    assert_eq!(a.table.rows.len(), b.table.rows.len(), "scale: row counts diverged");
    assert_eq!(a.series.len(), b.series.len(), "scale: series shape diverged");
    for (ra, rb) in a.table.rows.iter().zip(&b.table.rows) {
        for (ci, (ca, cb)) in ra.iter().zip(rb).enumerate() {
            if (2..=5).contains(&ci) {
                continue;
            }
            assert_eq!(ca, cb, "scale: cell diverged across worker counts");
        }
    }
}

#[test]
fn fig_sweeps_are_worker_count_invisible() {
    assert_result_identical("fig10", &fig10::run_jobs(&[512], 1), &fig10::run_jobs(&[512], 4), &[]);
    assert_result_identical("fig11", &fig11::run_jobs(&[512], 1), &fig11::run_jobs(&[512], 4), &[]);
    assert_result_identical(
        "fig12",
        &fig12::run_jobs(&[64, 128], 1),
        &fig12::run_jobs(&[64, 128], 4),
        &[],
    );
    assert_result_identical(
        "fig13",
        &fig13::run_jobs(&[64, 128], 1),
        &fig13::run_jobs(&[64, 128], 4),
        &[],
    );
}
