//! Property suite for the chaos (node-failure injection) subsystem.
//!
//! Three randomized families, 500 schedules each:
//!
//! - **Exactly-once reassignment**: random multi-session workloads run
//!   under random kill schedules (random times, random victims, FIFO
//!   and stealing requeue). Every session must complete with every
//!   task finished exactly once — a duplicate completion trips the
//!   scheduler's non-running assert, a lost task leaves the run
//!   undrained — the abort count must match the reported losses, and
//!   the whole chaotic run must replay bit-identically.
//! - **Post-recovery checksum integrity**: random datasets are staged,
//!   torn by random node failures, and re-staged (with the peer-copy
//!   recovery source both armed and disarmed). Afterwards every
//!   replica on every node must content-match the shared-FS original
//!   (length + checksum) and the residency mirror must still be exact.
//! - **Failure-rate-0 bit-identity**: with no kills scheduled, the
//!   `work_stealing` switch must be decision-invisible — virtual
//!   clock, completion times, and byte accounting bit-identical to the
//!   FIFO scheduler on every random workload.

use xstage::catalog::Catalog;
use xstage::cluster::{orthros, Topology};
use xstage::dataflow::sched::{SchedulerCfg, SessionId, SessionScheduler, SessionStats};
use xstage::dataflow::{Task, TaskGraph};
use xstage::engine::{Director, Notice, SimCore};
use xstage::mpisim::Comm;
use xstage::pfs::{Blob, GpfsParams};
use xstage::staging::{HookSpec, Residency};
use xstage::units::{Duration, SimTime, KIB, MB};
use xstage::util::prng::Pcg64;

/// Schedule count: `XSTAGE_PROP_SCHEDULES` if set, else 500.
fn schedules() -> u64 {
    xstage::util::prop_schedules(500)
}

// ---------------------------------------------------------------------
// Family 1: exactly-once reassignment under random kill schedules
// ---------------------------------------------------------------------

/// Paths staged on every node but absent from the shared FS: after a
/// kill, tasks placed on the torn node can only read them through the
/// peer-replica fallback.
const STAGED: &[&str] = &["/tmp/c0.bin", "/tmp/c1.bin"];
/// A path served from the shared FS only.
const UNSTAGED: &str = "/pfs/c2.bin";

struct Scenario {
    nodes: u32,
    ranks: u32,
    cache_inputs: bool,
    locality_aware: bool,
    graphs: Vec<TaskGraph>,
    /// (kill time, victim). Victims spare the last node so the staged
    /// paths always keep at least one surviving donor replica.
    kills: Vec<(Duration, u32)>,
}

fn scenario(seed: u64) -> Scenario {
    let mut rng = Pcg64::new(seed);
    let sessions = rng.range_u64(2, 6) as usize;
    let mut graphs = Vec::with_capacity(sessions);
    for s in 0..sessions {
        let mut g = TaskGraph::new();
        let n = rng.range_u64(2, 8) as usize;
        for t in 0..n {
            let mut task = Task::compute(
                format!("s{s}/t{t}"),
                Duration::from_secs_f64(rng.log_uniform(0.5, 10.0)),
            );
            if t > 0 && rng.f64() < 0.4 {
                let dep = rng.range_u64(0, t as u64 - 1) as usize;
                task = task.with_dep(xstage::dataflow::TaskId(dep));
            }
            match rng.range_u64(0, 3) {
                0 => task = task.with_input(STAGED[0], None),
                1 => task = task.with_input(STAGED[1], None),
                2 => task = task.with_input(UNSTAGED, None),
                _ => {}
            }
            g.add(task);
        }
        graphs.push(g);
    }
    let nodes = rng.range_u64(2, 4) as u32;
    let kills = (0..rng.range_u64(1, 3))
        .map(|_| {
            (
                Duration::from_secs_f64(rng.log_uniform(1.0, 40.0)),
                // Never the last node: a donor replica must survive.
                rng.below(nodes as u64 - 1) as u32,
            )
        })
        .collect();
    Scenario {
        nodes,
        ranks: rng.range_u64(1, 3) as u32,
        cache_inputs: rng.f64() < 0.5,
        locality_aware: rng.f64() < 0.5,
        graphs,
        kills,
    }
}

/// Kill timers are tagged `KILL_TAG + index` into [`Scenario::kills`].
const KILL_TAG: u64 = 1000;

struct KillBot {
    ss: SessionScheduler,
    victims: Vec<u32>,
    lost: usize,
}

impl Director for KillBot {
    fn on_notice(&mut self, core: &mut SimCore, notice: Notice) {
        match notice {
            Notice::Timer { tag } => {
                let node = self.victims[(tag - KILL_TAG) as usize];
                core.fail_node(node);
                self.lost += self.ss.on_node_failure(core, node);
            }
            Notice::PlanDone { tag, .. } => {
                self.ss.on_plan_done(core, tag);
            }
            _ => {}
        }
    }
}

fn run_killed(sc: &Scenario, steal: bool) -> (SimTime, Vec<SessionStats>, usize, u64) {
    let mut core = SimCore::new();
    let mut spec = orthros();
    spec.nodes = sc.nodes;
    spec.ranks_per_node = sc.ranks;
    let topo = Topology::build(spec, GpfsParams::default(), &mut core.net);
    let comm = Comm::world(&topo.spec);
    for p in STAGED {
        core.node_write_range(0, sc.nodes - 1, p, Blob::synthetic(2 * MB, 0xC4A0));
    }
    core.pfs.write(UNSTAGED, Blob::synthetic(2 * MB, 0xC4A1));
    let cfg = SchedulerCfg {
        cache_inputs: sc.cache_inputs,
        locality_aware: sc.locality_aware,
        work_stealing: steal,
        ..Default::default()
    };
    let mut ss = SessionScheduler::new(topo, comm, cfg);
    let sids: Vec<SessionId> =
        sc.graphs.iter().map(|g| ss.add_session(&mut core, g.clone())).collect();
    for (k, &(at, _)) in sc.kills.iter().enumerate() {
        core.timer(SimTime::ZERO + at, KILL_TAG + k as u64);
    }
    let mut bot = KillBot {
        ss,
        victims: sc.kills.iter().map(|&(_, v)| v).collect(),
        lost: 0,
    };
    core.run(&mut bot);
    assert!(bot.ss.all_done(), "a session never drained (task loss)");
    let aborted = core.metrics.count("chaos.plans.aborted");
    (core.now, sids.into_iter().map(|s| bot.ss.stats(s)).collect(), bot.lost, aborted)
}

#[test]
fn exactly_once_reassignment_on_500_random_kill_schedules() {
    for seed in 0..schedules() {
        let sc = scenario(seed);
        let steal = seed % 2 == 0;
        let (now, stats, lost, aborted) = run_killed(&sc, steal);
        // Exactly-once: every lost task maps to exactly one aborted
        // plan, and every task of every graph completed exactly once
        // (a duplicate completion would have tripped the scheduler's
        // non-running assert; a dropped one would have hung the run).
        assert_eq!(lost as u64, aborted, "losses != aborts (seed {seed})");
        for (i, (st, g)) in stats.iter().zip(&sc.graphs).enumerate() {
            assert_eq!(st.tasks_run, g.len(), "seed {seed} session {i}");
            assert_eq!(st.completion.len(), g.len());
            assert!(st.completion.iter().all(|&c| c > SimTime::ZERO));
        }
        // Chaotic replay is bit-identical.
        let (now2, stats2, lost2, _) = run_killed(&sc, steal);
        assert_eq!(now, now2, "virtual clock diverged on replay (seed {seed})");
        assert_eq!(lost, lost2);
        for (a, b) in stats.iter().zip(&stats2) {
            assert_eq!(a.completion, b.completion, "seed {seed}");
            assert_eq!(a.reads, b.reads, "seed {seed}");
        }
    }
}

// ---------------------------------------------------------------------
// Family 2: post-recovery replicas content-match the source
// ---------------------------------------------------------------------

#[test]
fn post_recovery_replicas_match_source_checksums_on_500_random_schedules() {
    for seed in 0..schedules() {
        let mut rng = Pcg64::new(0xC8A05 ^ seed);
        let nodes = rng.range_u64(2, 4) as u32;
        let files = rng.range_u64(2, 4) as usize;
        let mut core = SimCore::new();
        let mut spec = orthros();
        spec.nodes = nodes;
        let topo = Topology::build(spec, GpfsParams::default(), &mut core.net);
        let leader = Comm::leader(&topo.spec);
        for f in 0..files {
            core.pfs.write(
                format!("/projects/chaos/f{f}.bin"),
                Blob::synthetic(rng.range_u64(256 * KIB, 2 * MB), rng.next_u64()),
            );
        }
        let mut catalog = Catalog::new();
        let id = catalog.register("chaos-ds", "/projects/chaos", files as u64, 0);
        let mut res = Residency::new();
        res.bind(id, HookSpec::parse("broadcast to /tmp/chaos { /projects/chaos/*.bin }").unwrap());
        // Integrity must hold with the peer-copy recovery source both
        // armed and disarmed (disarmed recovers via GPFS re-read).
        res.peer_copy = rng.f64() < 0.5;
        res.stage_dataset(&mut core, &topo, &leader, id).unwrap();
        let rounds = rng.range_u64(1, 2);
        for _ in 0..rounds {
            if rng.f64() < 0.5 {
                res.unpin_dataset(&mut core, id);
            }
            core.fail_node(rng.below(nodes as u64) as u32);
            res.stage_dataset(&mut core, &topo, &leader, id).unwrap();
        }
        assert_eq!(core.metrics.count("chaos.node.failed"), rounds, "seed {seed}");
        // Every replica on every node matches the shared-FS original.
        for f in 0..files {
            let want = core.pfs.read(&format!("/projects/chaos/f{f}.bin")).unwrap().clone();
            for n in 0..nodes {
                let got = core.nodes.read(n, &format!("/tmp/chaos/f{f}.bin"));
                assert!(
                    got.is_some_and(|b| b.same_content(&want)),
                    "seed {seed}: /tmp/chaos/f{f}.bin checksum mismatch on node {n}"
                );
            }
        }
        assert!(core.residency.mirrors(&core.nodes), "mirror drifted (seed {seed})");
    }
}

// ---------------------------------------------------------------------
// Family 3: failure-rate 0 makes stealing decision-invisible
// ---------------------------------------------------------------------

#[test]
fn work_stealing_is_bit_identical_at_failure_rate_zero_on_500_random_schedules() {
    for seed in 0..schedules() {
        let mut sc = scenario(0xF0 ^ seed);
        sc.kills.clear(); // failure rate 0
        let (now_f, fifo, lost_f, _) = run_killed(&sc, false);
        let (now_s, steal, lost_s, _) = run_killed(&sc, true);
        assert_eq!(lost_f, 0);
        assert_eq!(lost_s, 0);
        assert_eq!(now_f, now_s, "virtual clock diverged (seed {seed})");
        for (i, (a, b)) in fifo.iter().zip(&steal).enumerate() {
            assert_eq!(a.completion, b.completion, "seed {seed} session {i}");
            assert_eq!(a.finished, b.finished, "seed {seed} session {i}");
            assert_eq!(a.reads, b.reads, "seed {seed} session {i}");
        }
    }
}
