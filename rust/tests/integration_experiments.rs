//! Integration: the experiment drivers regenerate every paper result
//! with the right *shape* (who wins, by what factor, where it
//! flattens). The precise headline endpoints are asserted in the
//! modules' own tests; here we check cross-experiment consistency and
//! that the CLI surfaces behave.

use xstage::experiments::{cache, fig10, fig11, fig12, fig13, reduction};
use xstage::units::GB;

#[test]
fn fig10_and_fig11_are_consistent() {
    // Fig 11's staged end-to-end bandwidth must be below Fig 10's
    // staging+write bandwidth (it adds the read phase) but within 2x.
    let (_, stage_bw) = fig10::run_point(8192);
    let phases = fig11::run_staged(8192);
    let e2e_bw = 8192.0 * xstage::experiments::DATASET_BYTES as f64 / phases.total_secs;
    assert!(e2e_bw < stage_bw, "e2e {e2e_bw} must be < staging {stage_bw}");
    assert!(e2e_bw > stage_bw / 2.0);
    // And the phase arithmetic must add up.
    assert!(
        (phases.stage_write_secs + phases.read_secs - phases.total_secs).abs() < 0.5,
        "{phases:?}"
    );
}

#[test]
fn headline_factor_between_4_and_6() {
    let staged = fig11::run_staged(8192).total_secs;
    let naive = fig11::run_naive(8192);
    let factor = naive / staged;
    assert!((4.0..6.0).contains(&factor), "input speedup {factor} (paper: 4.7x)");
}

#[test]
fn figure_tables_render_with_all_rows() {
    let r10 = fig10::run(&[512, 1024]);
    assert_eq!(r10.table.rows.len(), 2);
    assert!(r10.table.render().contains("1024"));
    let r11 = fig11::run(&[512]);
    assert_eq!(r11.table.rows.len(), 1);
    let r12 = fig12::run(&[64, 128]);
    assert_eq!(r12.table.rows.len(), 2);
    let r13 = fig13::run(&[64, 128]);
    assert_eq!(r13.table.rows.len(), 2);
    let red = reduction::run();
    assert_eq!(red.table.rows.len(), 5);
    let c = cache::run();
    assert_eq!(c.table.rows.len(), 2);
}

#[test]
fn sweeps_are_deterministic() {
    let a = fig12::run_point(320, 42);
    let b = fig12::run_point(320, 42);
    assert_eq!(a, b);
    let c = fig12::run_point(320, 43);
    assert_ne!(a, c, "different seeds must differ");
}

#[test]
fn staging_beats_gpfs_peak_at_scale() {
    // Sanity: no experiment reports more aggregate bandwidth than the
    // hardware could deliver through its bottleneck layers.
    let pts = fig10::run(&[8192]);
    let bw = pts.series_named("staging+write GB/s").unwrap()[0].1;
    // ION layer ceiling: 64 IONs x 2.1 GB/s = 134.4 GB/s.
    assert!(bw <= 134.4 + 0.5, "{bw} exceeds the ION ceiling");
    // Naive never exceeds GPFS peak.
    let naive = fig11::run_naive(8192);
    let naive_bw = 8192.0 * xstage::experiments::DATASET_BYTES as f64 / naive;
    assert!(naive_bw <= 240.0 * GB as f64);
}
