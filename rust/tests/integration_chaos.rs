//! Integration tests for chaos serving: arming the fault-injection
//! machinery must be invisible until a kill actually fires, and a
//! genuinely chaotic run must drain completely and replay
//! bit-identically.

use xstage::chaos::ChaosCfg;
use xstage::dataflow::sched::SchedulerCfg;
use xstage::simtime::flownet::ThroughputMode;
use xstage::staging::service::{run_serve, ServeMode, ServiceCfg};
use xstage::units::MB;

fn cfg(stealing: bool, chaos: Option<ChaosCfg>) -> ServiceCfg {
    ServiceCfg {
        seed: 77,
        sessions: 10,
        mean_gap_secs: 18.0,
        datasets: 3,
        files_per_dataset: 4,
        file_bytes: 8 * MB,
        mode: ServeMode::Staged,
        sched: SchedulerCfg {
            locality_aware: true,
            work_stealing: stealing,
            ..Default::default()
        },
        chaos,
        ..Default::default()
    }
}

#[test]
fn zero_failure_chaos_and_stealing_are_bit_identical_to_seed_scheduler() {
    // The acceptance bar: at failure rate 0, neither arming the chaos
    // config nor enabling work stealing may change a single decision —
    // the turnaround table, virtual clock, byte accounting, and read
    // stats must be bit-identical to the seed FIFO scheduler.
    let baseline = run_serve(2, &cfg(false, None), ThroughputMode::Fast);
    let zero = ChaosCfg { failures: 0, ..Default::default() };
    for (label, variant) in [
        ("stealing on", cfg(true, None)),
        ("chaos armed at rate 0", cfg(false, Some(zero))),
        ("both", cfg(true, Some(zero))),
    ] {
        let out = run_serve(2, &variant, ThroughputMode::Fast);
        assert_eq!(out.turnaround_secs, baseline.turnaround_secs, "{label}");
        assert_eq!(out.virtual_secs, baseline.virtual_secs, "{label}");
        assert_eq!(out.staged_bytes, baseline.staged_bytes, "{label}");
        assert_eq!(out.promoted_bytes, baseline.promoted_bytes, "{label}");
        assert_eq!(out.reads, baseline.reads, "{label}");
        assert_eq!(out.node_failures, 0, "{label}");
        assert_eq!(out.lost_tasks, 0, "{label}");
        assert_eq!(out.copied_bytes, 0, "{label}");
    }
}

#[test]
fn chaotic_runs_drain_and_replay_bit_identically() {
    let chaotic = ChaosCfg { seed: 3, failures: 4, mean_gap_secs: 60.0 };
    for stealing in [false, true] {
        let c = cfg(stealing, Some(chaotic));
        // `run_serve` asserts internally that every session completed.
        let a = run_serve(3, &c, ThroughputMode::Fast);
        let b = run_serve(3, &c, ThroughputMode::Fast);
        assert_eq!(a.node_failures, 4, "stealing {stealing}");
        assert_eq!(a.turnaround_secs, b.turnaround_secs, "stealing {stealing}");
        assert_eq!(a.lost_tasks, b.lost_tasks);
        assert_eq!(a.copied_bytes, b.copied_bytes);
        assert_eq!(a.staged_bytes, b.staged_bytes);
        assert_eq!(a.virtual_secs, b.virtual_secs);
        // Recovery never routes a task read to the shared FS.
        assert_eq!(a.reads.unstaged_bytes, 0);
    }
}

#[test]
fn throughput_models_agree_under_chaos() {
    // Flow cancellation rides the same completion hook in both
    // throughput models, so a chaotic run must produce the same
    // turnarounds under the fast incremental model and the slow
    // reference model.
    let c = cfg(true, Some(ChaosCfg { seed: 5, failures: 3, mean_gap_secs: 70.0 }));
    let fast = run_serve(2, &c, ThroughputMode::Fast);
    let slow = run_serve(2, &c, ThroughputMode::Slow);
    assert_eq!(fast.node_failures, slow.node_failures);
    assert_eq!(fast.lost_tasks, slow.lost_tasks);
    for (f, s) in fast.turnaround_secs.iter().zip(&slow.turnaround_secs) {
        assert!((f - s).abs() < 1e-5, "fast {f} vs slow {s}");
    }
}
