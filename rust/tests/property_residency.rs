//! Property suite for the node-memory residency manager.
//!
//! Drives randomized stage/read(touch)/evict/pin schedules against
//! [`xstage::cluster::NodeStores`] through the engine's synchronized
//! entry points (`SimCore::node_write_range` / `SimCore::evict_path`)
//! and, in lockstep, against an independent naive shadow model of the
//! documented semantics. After **every** step it asserts the residency
//! invariants:
//!
//! - per-node resident bytes never exceed the capacity;
//! - pinned replicas are never evicted (capacity pressure or forced);
//! - LRU victim ordering is respected: every eviction the store
//!   performs matches the shadow's least-(last_use, seq) choice, in
//!   order, and rejected writes leave the store untouched;
//! - the engine's residency table exactly mirrors `NodeStores`
//!   contents.
//!
//! The schedules run under both throughput models (the store must be
//! oblivious to the flow network, and the acceptance bar demands it).

use std::collections::BTreeMap;

use xstage::cluster::StoreWrite;
use xstage::engine::SimCore;
use xstage::pfs::Blob;
use xstage::simtime::flownet::ThroughputMode;
use xstage::util::prng::Pcg64;

const NODES: u32 = 6;
const PATHS: &[&str] = &[
    "/tmp/a.bin",
    "/tmp/b.bin",
    "/tmp/c.bin",
    "/tmp/d.bin",
    "/tmp/e.bin",
    "/tmp/f.bin",
    "/tmp/g.bin",
    "/tmp/h.bin",
];
const STEPS: usize = 30;
/// Schedule count: `XSTAGE_PROP_SCHEDULES` if set, else 500.
fn schedules() -> u64 {
    xstage::util::prop_schedules(500)
}

/// One shadow replica (same semantics as the store's internal one).
#[derive(Clone, Debug)]
struct Rep {
    path: String,
    lo: u32,
    hi: u32,
    len: u64,
    seed: u64,
    last_use: u64,
    seq: u64,
}

/// Victims of one shadow write: (path, lo, hi, per-node bytes), in
/// eviction order.
type Victims = Vec<(String, u32, u32, u64)>;

impl Rep {
    fn covers(&self, n: u32) -> bool {
        (self.lo..=self.hi).contains(&n)
    }

    fn overlaps(&self, lo: u32, hi: u32) -> bool {
        self.lo <= hi && self.hi >= lo
    }
}

/// Naive reimplementation of the documented NodeStores semantics.
#[derive(Default)]
struct Shadow {
    cap: u64,
    reps: Vec<Rep>,
    /// Refcounted pins, like the store's.
    pinned: BTreeMap<String, u32>,
    clock: u64,
    seq: u64,
}

impl Shadow {
    fn used(&self, n: u32) -> u64 {
        self.reps.iter().filter(|r| r.covers(n)).map(|r| r.len).sum()
    }

    fn pin(&mut self, path: &str) {
        *self.pinned.entry(path.to_string()).or_insert(0) += 1;
    }

    fn unpin(&mut self, path: &str) {
        if let Some(n) = self.pinned.get_mut(path) {
            *n -= 1;
            if *n == 0 {
                self.pinned.remove(path);
            }
        }
    }

    /// Keep (path, lo) iteration order identical to the store's
    /// BTreeMap-of-sorted-vecs enumeration.
    fn sort(&mut self) {
        self.reps.sort_by(|a, b| (a.path.as_str(), a.lo).cmp(&(b.path.as_str(), b.lo)));
    }

    /// The documented write spec. Some(victims in eviction order) when
    /// stored; None when rejected (state untouched).
    fn write(
        &mut self,
        lo: u32,
        hi: u32,
        path: &str,
        len: u64,
        seed: u64,
    ) -> Option<Victims> {
        if len > self.cap {
            return None;
        }
        // Feasibility: with every evictable victim gone, only pinned
        // other-path replicas remain.
        for n in lo..=hi {
            let kept: u64 = self
                .reps
                .iter()
                .filter(|r| r.covers(n) && r.path != path && self.pinned.contains_key(&r.path))
                .map(|r| r.len)
                .sum();
            if kept + len > self.cap {
                return None;
            }
        }
        // Evict least-(last_use, seq) victims covering an over-budget
        // node of the range.
        let mut victims = Vec::new();
        loop {
            let post = |sh: &Self, n: u32| {
                let mut u = sh.used(n);
                if let Some(r) = sh.reps.iter().find(|r| r.path == path && r.covers(n)) {
                    u -= r.len;
                }
                u
            };
            let over: Vec<u32> =
                (lo..=hi).filter(|&n| post(self, n) + len > self.cap).collect();
            if over.is_empty() {
                break;
            }
            self.sort();
            let idx = self
                .reps
                .iter()
                .enumerate()
                .filter(|(_, r)| {
                    r.path != path
                        && !self.pinned.contains_key(&r.path)
                        && over.iter().any(|&n| r.covers(n))
                })
                .min_by_key(|(_, r)| (r.last_use, r.seq))
                .map(|(i, _)| i)
                .expect("feasibility check promised an evictable victim");
            let r = self.reps.remove(idx);
            victims.push((r.path, r.lo, r.hi, r.len));
        }
        // Replace same-path overlap, then insert.
        self.clock += 1;
        self.seq += 1;
        let (now, seq) = (self.clock, self.seq);
        let mut next = Vec::with_capacity(self.reps.len() + 1);
        for r in self.reps.drain(..) {
            if r.path != path || !r.overlaps(lo, hi) {
                next.push(r);
                continue;
            }
            if r.lo < lo {
                next.push(Rep { hi: lo - 1, ..r.clone() });
            }
            if r.hi > hi {
                next.push(Rep { lo: hi + 1, ..r });
            }
        }
        next.push(Rep { path: path.to_string(), lo, hi, len, seed, last_use: now, seq });
        self.reps = next;
        Some(victims)
    }

    fn touch(&mut self, node: u32, path: &str) {
        self.clock += 1;
        let now = self.clock;
        if let Some(r) = self.reps.iter_mut().find(|r| r.path == path && r.covers(node)) {
            r.last_use = now;
        }
    }

    fn touch_range(&mut self, lo: u32, hi: u32, path: &str) {
        self.clock += 1;
        let now = self.clock;
        for r in self.reps.iter_mut().filter(|r| r.path == path && r.overlaps(lo, hi)) {
            r.last_use = now;
        }
    }

    /// Forced eviction; returns the removed replicas sorted by lo.
    fn evict_path(&mut self, path: &str) -> Vec<(u32, u32, u64)> {
        if self.pinned.contains_key(path) {
            return Vec::new();
        }
        let mut out: Vec<(u32, u32, u64)> = self
            .reps
            .iter()
            .filter(|r| r.path == path)
            .map(|r| (r.lo, r.hi, r.len))
            .collect();
        out.sort_unstable();
        self.reps.retain(|r| r.path != path);
        out
    }
}

/// Assert every invariant, comparing the store against the shadow.
fn check(core: &SimCore, sh: &Shadow, cap: u64) {
    for n in 0..NODES {
        let got = core.nodes.bytes_on(n);
        assert!(got <= cap, "node {n}: {got} B resident > capacity {cap}");
        assert_eq!(got, sh.used(n), "node {n}: usage diverged from shadow");
    }
    for n in 0..NODES {
        let mut want: Vec<String> = sh
            .reps
            .iter()
            .filter(|r| r.covers(n))
            .map(|r| r.path.clone())
            .collect();
        want.sort();
        want.dedup();
        assert_eq!(core.nodes.paths_on(n), want, "paths on node {n} diverged");
        for r in sh.reps.iter().filter(|r| r.covers(n)) {
            let got = core.nodes.read(n, &r.path).expect("shadow replica missing in store");
            assert!(
                got.same_content(&Blob::synthetic(r.len, r.seed)),
                "content of {} diverged on node {n}",
                r.path
            );
        }
    }
    assert!(
        core.residency.mirrors(&core.nodes),
        "residency table no longer mirrors NodeStores"
    );
}

fn drive(mode: ThroughputMode, schedule_seed: u64) {
    let mut rng = Pcg64::new(schedule_seed);
    let cap = rng.range_u64(60, 160);
    let mut core = SimCore::with_mode(mode);
    core.nodes.set_capacity(Some(cap));
    let mut sh = Shadow { cap, ..Default::default() };

    for step in 0..STEPS {
        match rng.below(10) {
            // Stage: a capacity-checked replicated write.
            0..=4 => {
                let lo = rng.below(NODES as u64) as u32;
                let hi = rng.range_u64(lo as u64, NODES as u64 - 1) as u32;
                let path = PATHS[rng.below(PATHS.len() as u64) as usize];
                let len = rng.range_u64(1, 80);
                let seed = rng.next_u64() | 1;
                let got = core.node_write_range(lo, hi, path, Blob::synthetic(len, seed));
                let want = sh.write(lo, hi, path, len, seed);
                match (&got, &want) {
                    (StoreWrite::Stored { evicted }, Some(victims)) => {
                        assert_eq!(
                            evicted.len(),
                            victims.len(),
                            "step {step}: eviction count diverged"
                        );
                        for (e, (vp, vlo, vhi, vlen)) in evicted.iter().zip(victims) {
                            assert_eq!(
                                (&e.path, e.lo, e.hi, e.bytes),
                                (vp, *vlo, *vhi, *vlen),
                                "step {step}: LRU victim order diverged"
                            );
                            assert!(
                                !sh.pinned.contains_key(&e.path),
                                "step {step}: pinned replica {} evicted",
                                e.path
                            );
                        }
                    }
                    (StoreWrite::Rejected { .. }, None) => {}
                    (g, w) => panic!("step {step}: outcome diverged: {g:?} vs shadow {w:?}"),
                }
            }
            // Read: refreshes LRU recency (single node or whole range).
            5..=6 => {
                let path = PATHS[rng.below(PATHS.len() as u64) as usize];
                if rng.below(2) == 0 {
                    let node = rng.below(NODES as u64) as u32;
                    core.nodes.touch(node, path);
                    sh.touch(node, path);
                } else {
                    let lo = rng.below(NODES as u64) as u32;
                    let hi = rng.range_u64(lo as u64, NODES as u64 - 1) as u32;
                    core.nodes.touch_range(lo, hi, path);
                    sh.touch_range(lo, hi, path);
                }
            }
            // Pin / unpin.
            7 => {
                let path = PATHS[rng.below(PATHS.len() as u64) as usize];
                if rng.below(2) == 0 {
                    core.nodes.pin(path.to_string());
                    sh.pin(path);
                } else {
                    core.nodes.unpin(path);
                    sh.unpin(path);
                }
            }
            // Forced eviction (no-op on pinned paths).
            _ => {
                let path = PATHS[rng.below(PATHS.len() as u64) as usize];
                let got = core.evict_path(path);
                let want = sh.evict_path(path);
                let got_ranges: Vec<(u32, u32, u64)> =
                    got.iter().map(|e| (e.lo, e.hi, e.bytes)).collect();
                assert_eq!(got_ranges, want, "step {step}: forced eviction diverged");
                for e in &got {
                    assert!(!sh.pinned.contains_key(&e.path), "pinned replica force-evicted");
                }
            }
        }
        check(&core, &sh, cap);
    }
}

#[test]
fn residency_invariants_hold_fast_model() {
    for s in 0..schedules() {
        drive(ThroughputMode::Fast, 0x5EED_0000 + s);
    }
}

#[test]
fn residency_invariants_hold_slow_model() {
    for s in 0..schedules() {
        drive(ThroughputMode::Slow, 0xA5EED_000 + s);
    }
}

// ---------------------------------------------------------------------
// tiered shadow: RAM + SSD with demotion, cascade discards, promotion
// ---------------------------------------------------------------------

use xstage::storage::{PromoteOutcome, StorageTier};

/// One tier's victims for one write, in eviction order.
type TierVictims = Vec<Rep>;

/// One displacement record mirrored against [`xstage::storage::Eviction`].
#[derive(Debug, PartialEq)]
struct ShadowEv {
    path: String,
    lo: u32,
    hi: u32,
    len: u64,
    tier: StorageTier,
    demoted: bool,
}

/// Naive reimplementation of the documented *tiered* NodeStores
/// semantics: a RAM tier whose victims demote whole into an SSD tier
/// (own capacity, own LRU discards), sharing one pin set and one
/// clock/seq stream, plus SSD -> RAM promotion.
#[derive(Default)]
struct TieredShadow {
    ram_cap: u64,
    ssd_cap: Option<u64>,
    ram: Vec<Rep>,
    ssd: Vec<Rep>,
    pinned: BTreeMap<String, u32>,
    clock: u64,
    seq: u64,
}

impl TieredShadow {
    fn used(reps: &[Rep], n: u32) -> u64 {
        reps.iter().filter(|r| r.covers(n)).map(|r| r.len).sum()
    }

    fn sort(reps: &mut [Rep]) {
        reps.sort_by(|a, b| (a.path.as_str(), a.lo).cmp(&(b.path.as_str(), b.lo)));
    }

    /// The documented single-tier write spec against one tier's rep
    /// list. Some(victims in eviction order) when stored; None when
    /// rejected (tier untouched). Bumps clock/seq once on success —
    /// exactly the store's `TierStore::write_range_evicting`.
    #[allow(clippy::too_many_arguments)]
    fn tier_write(
        reps: &mut Vec<Rep>,
        cap: u64,
        pinned: &BTreeMap<String, u32>,
        clock: &mut u64,
        seq: &mut u64,
        lo: u32,
        hi: u32,
        path: &str,
        len: u64,
        seed: u64,
    ) -> Option<TierVictims> {
        if len > cap {
            return None;
        }
        for n in lo..=hi {
            let kept: u64 = reps
                .iter()
                .filter(|r| r.covers(n) && r.path != path && pinned.contains_key(&r.path))
                .map(|r| r.len)
                .sum();
            if kept + len > cap {
                return None;
            }
        }
        let mut victims = Vec::new();
        loop {
            let post = |reps: &[Rep], n: u32| {
                let mut u = Self::used(reps, n);
                if let Some(r) = reps.iter().find(|r| r.path == path && r.covers(n)) {
                    u -= r.len;
                }
                u
            };
            let over: Vec<u32> = (lo..=hi).filter(|&n| post(reps, n) + len > cap).collect();
            if over.is_empty() {
                break;
            }
            Self::sort(reps);
            let idx = reps
                .iter()
                .enumerate()
                .filter(|(_, r)| {
                    r.path != path
                        && !pinned.contains_key(&r.path)
                        && over.iter().any(|&n| r.covers(n))
                })
                .min_by_key(|(_, r)| (r.last_use, r.seq))
                .map(|(i, _)| i)
                .expect("feasibility check promised an evictable victim");
            victims.push(reps.remove(idx));
        }
        *clock += 1;
        *seq += 1;
        let (now, sq) = (*clock, *seq);
        let mut next = Vec::with_capacity(reps.len() + 1);
        for r in reps.drain(..) {
            if r.path != path || !r.overlaps(lo, hi) {
                next.push(r);
                continue;
            }
            if r.lo < lo {
                next.push(Rep { hi: lo - 1, ..r.clone() });
            }
            if r.hi > hi {
                next.push(Rep { lo: hi + 1, ..r });
            }
        }
        next.push(Rep { path: path.to_string(), lo, hi, len, seed, last_use: now, seq: sq });
        *reps = next;
        Some(victims)
    }

    /// The tiered write: RAM admission, then per-victim demotion into
    /// SSD (cascade discards interleaved after their cause).
    fn write(
        &mut self,
        lo: u32,
        hi: u32,
        path: &str,
        len: u64,
        seed: u64,
    ) -> Option<Vec<ShadowEv>> {
        let victims = Self::tier_write(
            &mut self.ram,
            self.ram_cap,
            &self.pinned,
            &mut self.clock,
            &mut self.seq,
            lo,
            hi,
            path,
            len,
            seed,
        )?;
        Some(self.demote(victims))
    }

    fn demote(&mut self, victims: Vec<Rep>) -> Vec<ShadowEv> {
        let mut out = Vec::new();
        for v in victims {
            let mut demoted = false;
            let mut cascade = Vec::new();
            if let Some(cap) = self.ssd_cap {
                if let Some(c) = Self::tier_write(
                    &mut self.ssd,
                    cap,
                    &self.pinned,
                    &mut self.clock,
                    &mut self.seq,
                    v.lo,
                    v.hi,
                    &v.path,
                    v.len,
                    v.seed,
                ) {
                    demoted = true;
                    cascade = c;
                }
            }
            out.push(ShadowEv {
                path: v.path.clone(),
                lo: v.lo,
                hi: v.hi,
                len: v.len,
                tier: StorageTier::Ram,
                demoted,
            });
            for c in cascade {
                out.push(ShadowEv {
                    path: c.path,
                    lo: c.lo,
                    hi: c.hi,
                    len: c.len,
                    tier: StorageTier::Ssd,
                    demoted: false,
                });
            }
        }
        out
    }

    /// SSD -> RAM promotion: full-coverage uniform-content check, RAM
    /// admission (victims demote as usual), SSD range removal.
    /// None = Missing, Some(None) = Rejected, Some(Some(evs)) = Promoted.
    #[allow(clippy::type_complexity)]
    fn promote(&mut self, lo: u32, hi: u32, path: &str) -> Option<Option<Vec<ShadowEv>>> {
        let first = self.ssd.iter().find(|r| r.path == path && r.covers(lo))?;
        let (len, seed) = (first.len, first.seed);
        let mut covered = 0u64;
        for r in self.ssd.iter().filter(|r| r.path == path && r.overlaps(lo, hi)) {
            if (r.len, r.seed) != (len, seed) {
                return None;
            }
            covered += (r.hi.min(hi) - r.lo.max(lo) + 1) as u64;
        }
        if covered != (hi - lo + 1) as u64 {
            return None;
        }
        let Some(evs) = self.write(lo, hi, path, len, seed) else {
            return Some(None);
        };
        // Remove the promoted portion from SSD (split stragglers).
        let mut next = Vec::with_capacity(self.ssd.len() + 1);
        for r in self.ssd.drain(..) {
            if r.path != path || !r.overlaps(lo, hi) {
                next.push(r);
                continue;
            }
            if r.lo < lo {
                next.push(Rep { hi: lo - 1, ..r.clone() });
            }
            if r.hi > hi {
                next.push(Rep { lo: hi + 1, ..r });
            }
        }
        self.ssd = next;
        Some(Some(evs))
    }

    fn touch_range(&mut self, lo: u32, hi: u32, path: &str) {
        self.clock += 1;
        let now = self.clock;
        for r in self.ram.iter_mut().filter(|r| r.path == path && r.overlaps(lo, hi)) {
            r.last_use = now;
        }
    }

    fn evict_path(&mut self, path: &str) -> Vec<ShadowEv> {
        if self.pinned.contains_key(path) {
            return Vec::new();
        }
        let mut out = Vec::new();
        for (tier, reps) in
            [(StorageTier::Ram, &mut self.ram), (StorageTier::Ssd, &mut self.ssd)]
        {
            let mut gone: Vec<&Rep> = reps.iter().filter(|r| r.path == path).collect();
            gone.sort_by_key(|r| r.lo);
            for r in gone {
                out.push(ShadowEv {
                    path: r.path.clone(),
                    lo: r.lo,
                    hi: r.hi,
                    len: r.len,
                    tier,
                    demoted: false,
                });
            }
            reps.retain(|r| r.path != path);
        }
        out
    }
}

/// Assert every tiered invariant, comparing both store tiers against
/// the shadow.
fn check_tiered(core: &SimCore, sh: &TieredShadow) {
    for (tier, reps, cap) in [
        (StorageTier::Ram, &sh.ram, Some(sh.ram_cap)),
        (StorageTier::Ssd, &sh.ssd, sh.ssd_cap),
    ] {
        for n in 0..NODES {
            let got = core.nodes.bytes_on_tier(tier, n);
            if let Some(cap) = cap {
                assert!(got <= cap, "{tier:?} node {n}: {got} B resident > capacity {cap}");
            }
            assert_eq!(
                got,
                TieredShadow::used(reps, n),
                "{tier:?} node {n}: usage diverged from shadow"
            );
            for r in reps.iter().filter(|r| r.covers(n)) {
                let got = core
                    .nodes
                    .read_tier(tier, n, &r.path)
                    .unwrap_or_else(|| panic!("{tier:?}: shadow replica {} missing", r.path));
                assert!(
                    got.same_content(&Blob::synthetic(r.len, r.seed)),
                    "{tier:?}: content of {} diverged on node {n}",
                    r.path
                );
            }
        }
    }
    assert!(
        core.residency.mirrors(&core.nodes),
        "residency table no longer mirrors the tiered NodeStores"
    );
}

/// Compare the store's eviction records against the shadow's, field
/// by field (order, tier, demotion flag), and assert pins never
/// appear.
fn check_evictions(
    step: usize,
    got: &[xstage::storage::Eviction],
    want: &[ShadowEv],
    pinned: &BTreeMap<String, u32>,
) {
    assert_eq!(got.len(), want.len(), "step {step}: displacement count diverged");
    for (g, w) in got.iter().zip(want) {
        assert_eq!(
            (&g.path, g.lo, g.hi, g.bytes, g.tier, g.demoted),
            (&w.path, w.lo, w.hi, w.len, w.tier, w.demoted),
            "step {step}: displacement record diverged"
        );
        assert!(
            !pinned.contains_key(&g.path),
            "step {step}: pinned replica {} displaced",
            g.path
        );
    }
}

fn drive_tiered(mode: ThroughputMode, schedule_seed: u64) {
    let mut rng = Pcg64::new(schedule_seed);
    let ram_cap = rng.range_u64(60, 160);
    let ssd_cap = rng.range_u64(60, 200);
    let mut core = SimCore::with_mode(mode);
    core.nodes.set_capacity(Some(ram_cap));
    core.nodes.set_ssd_capacity(Some(ssd_cap));
    let mut sh = TieredShadow { ram_cap, ssd_cap: Some(ssd_cap), ..Default::default() };

    for step in 0..STEPS {
        match rng.below(10) {
            // Stage: a capacity-checked tiered write (victims demote).
            0..=3 => {
                let lo = rng.below(NODES as u64) as u32;
                let hi = rng.range_u64(lo as u64, NODES as u64 - 1) as u32;
                let path = PATHS[rng.below(PATHS.len() as u64) as usize];
                let len = rng.range_u64(1, 80);
                let seed = rng.next_u64() | 1;
                let got = core.node_write_range(lo, hi, path, Blob::synthetic(len, seed));
                let want = sh.write(lo, hi, path, len, seed);
                match (&got, &want) {
                    (StoreWrite::Stored { evicted }, Some(evs)) => {
                        check_evictions(step, evicted, evs, &sh.pinned);
                        // Demotion preserves bytes + checksums: every
                        // demoted replica is readable on SSD with its
                        // original content.
                        for e in evicted.iter().filter(|e| e.demoted) {
                            let r = sh
                                .ssd
                                .iter()
                                .find(|r| r.path == e.path && r.covers(e.lo))
                                .expect("demoted replica absent from shadow SSD");
                            let got = core
                                .nodes
                                .read_tier(StorageTier::Ssd, e.lo, &e.path)
                                .expect("demoted replica absent from store SSD");
                            assert!(got.same_content(&Blob::synthetic(r.len, r.seed)));
                        }
                    }
                    (StoreWrite::Rejected { .. }, None) => {}
                    (g, w) => panic!("step {step}: outcome diverged: {g:?} vs shadow {w:?}"),
                }
            }
            // Promote: SSD -> RAM (restores RAM residency).
            4..=5 => {
                let lo = rng.below(NODES as u64) as u32;
                let hi = rng.range_u64(lo as u64, NODES as u64 - 1) as u32;
                let path = PATHS[rng.below(PATHS.len() as u64) as usize];
                let got = core.promote_range(lo, hi, path);
                let want = sh.promote(lo, hi, path);
                match (&got, &want) {
                    (PromoteOutcome::Promoted { evicted, .. }, Some(Some(evs))) => {
                        check_evictions(step, evicted, evs, &sh.pinned);
                        // Promotion restores RAM residency across the
                        // whole range.
                        for n in lo..=hi {
                            assert!(
                                core.nodes.exists_on(n, path),
                                "step {step}: promoted {path} absent from RAM on {n}"
                            );
                        }
                    }
                    (PromoteOutcome::Rejected { .. }, Some(None)) => {}
                    (PromoteOutcome::Missing, None) => {}
                    (g, w) => {
                        panic!("step {step}: promote outcome diverged: {g:?} vs shadow {w:?}")
                    }
                }
            }
            // Read: refreshes LRU recency on the RAM tier.
            6 => {
                let lo = rng.below(NODES as u64) as u32;
                let hi = rng.range_u64(lo as u64, NODES as u64 - 1) as u32;
                let path = PATHS[rng.below(PATHS.len() as u64) as usize];
                core.nodes.touch_range(lo, hi, path);
                sh.touch_range(lo, hi, path);
            }
            // Pin / unpin (protects both tiers).
            7..=8 => {
                let path = PATHS[rng.below(PATHS.len() as u64) as usize];
                if rng.below(2) == 0 {
                    core.nodes.pin(path.to_string());
                    *sh.pinned.entry(path.to_string()).or_insert(0) += 1;
                } else {
                    core.nodes.unpin(path);
                    if let Some(n) = sh.pinned.get_mut(path) {
                        *n -= 1;
                        if *n == 0 {
                            sh.pinned.remove(path);
                        }
                    }
                }
            }
            // Forced eviction: purges both tiers (no-op when pinned).
            _ => {
                let path = PATHS[rng.below(PATHS.len() as u64) as usize];
                let got = core.evict_path(path);
                let want = sh.evict_path(path);
                check_evictions(step, &got, &want, &sh.pinned);
            }
        }
        check_tiered(&core, &sh);
    }
}

#[test]
fn tiered_invariants_hold_fast_model() {
    for s in 0..schedules() {
        drive_tiered(ThroughputMode::Fast, 0x71E2_0000 + s);
    }
}

#[test]
fn tiered_invariants_hold_slow_model() {
    for s in 0..schedules() {
        drive_tiered(ThroughputMode::Slow, 0xA71E2_000 + s);
    }
}
