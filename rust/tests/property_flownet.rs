//! Property tests (seeded randomized, in-tree harness): invariants of
//! the max-min fair-share flow network and the plan executor that the
//! whole timing model rests on — plus the slow-vs-fast differential
//! suite that proves the component-incremental throughput model
//! behaviourally equivalent to the global reference pass.

use xstage::simtime::flownet::{Capacity, FlowId, FlowNet, LinkId, ThroughputMode};
use xstage::units::{Duration, SimTime};
use xstage::util::prng::Pcg64;

/// Build a random network + active flow set (fast model).
fn random_net(seed: u64) -> (FlowNet, Vec<LinkId>, Vec<FlowId>) {
    let mut rng = Pcg64::new(seed);
    let mut net = FlowNet::new();
    let nlinks = 2 + rng.below(6) as usize;
    let links: Vec<LinkId> = (0..nlinks)
        .map(|i| {
            let cap = rng.range_f64(1e8, 1e11);
            if rng.f64() < 0.3 {
                net.add_link(
                    format!("l{i}"),
                    Capacity::Degrading { peak: cap, pivot: rng.range_f64(1.0, 1e4), half: rng.range_f64(10.0, 1e4) },
                )
            } else {
                net.add_link(format!("l{i}"), Capacity::Fixed(cap))
            }
        })
        .collect();
    let nflows = 1 + rng.below(30) as usize;
    let mut flows = Vec::new();
    for _ in 0..nflows {
        let plen = 1 + rng.below((links.len() as u64).min(3)) as usize;
        let mut path = Vec::new();
        for _ in 0..plen {
            let l = links[rng.below(links.len() as u64) as usize];
            if !path.contains(&l) {
                path.push(l);
            }
        }
        let members = 1 + rng.below(10_000);
        let bytes = 1 + rng.below(1 << 32);
        let cap = if rng.f64() < 0.3 {
            rng.range_f64(1e6, 1e10)
        } else {
            f64::INFINITY
        };
        flows.push(net.start_capped(path, members, bytes, cap));
    }
    net.recompute();
    (net, links, flows)
}

#[test]
fn rates_are_nonnegative_and_capped() {
    for seed in 0..200 {
        let (net, _, flows) = random_net(seed);
        for f in flows {
            let r = net.rate_each(f);
            assert!(r >= 0.0, "seed {seed}: negative rate");
            assert!(r.is_finite() || r == f64::INFINITY, "seed {seed}: NaN rate");
        }
    }
}

#[test]
fn no_link_oversubscribed() {
    // Sum of member-rates through any fixed link <= its capacity
    // (within FP tolerance). We re-derive loads by replaying flows.
    for seed in 0..200 {
        let mut rng = Pcg64::new(seed);
        let mut net = FlowNet::new();
        let nlinks = 2 + rng.below(5) as usize;
        let caps: Vec<f64> = (0..nlinks).map(|_| rng.range_f64(1e8, 1e11)).collect();
        let links: Vec<LinkId> = caps
            .iter()
            .enumerate()
            .map(|(i, &c)| net.add_link(format!("l{i}"), Capacity::Fixed(c)))
            .collect();
        let mut flow_info = Vec::new();
        for _ in 0..(1 + rng.below(25)) {
            let l1 = links[rng.below(nlinks as u64) as usize];
            let l2 = links[rng.below(nlinks as u64) as usize];
            let path = if l1 == l2 { vec![l1] } else { vec![l1, l2] };
            let members = 1 + rng.below(5_000);
            let f = net.start(path.clone(), members, 1 << 30);
            flow_info.push((f, path, members));
        }
        net.recompute();
        let mut load = vec![0f64; nlinks];
        for (f, path, members) in &flow_info {
            let r = net.rate_each(*f);
            for l in path {
                load[l.0] += r * *members as f64;
            }
        }
        for (i, &l) in load.iter().enumerate() {
            assert!(
                l <= caps[i] * (1.0 + 1e-6),
                "seed {seed}: link {i} oversubscribed: {l} > {}",
                caps[i]
            );
        }
    }
}

#[test]
fn work_conserving_on_single_link() {
    // One fixed link, arbitrary uncapped flows: fully utilised.
    for seed in 0..100 {
        let mut rng = Pcg64::new(1000 + seed);
        let mut net = FlowNet::new();
        let cap = rng.range_f64(1e8, 1e10);
        let l = net.add_link("l", Capacity::Fixed(cap));
        for _ in 0..(1 + rng.below(20)) {
            net.start(vec![l], 1 + rng.below(100), 1 << 28);
        }
        net.recompute();
        // Utilisation check via the drain loop: max-min on a single
        // link is work-conserving, so the drain makes progress until
        // every flow is done.
        let mut t = 0.0f64;
        let mut now = SimTime::ZERO;
        loop {
            let Some((eta, f)) = net.next_completion(now) else { break };
            let dt = eta - now;
            net.advance(dt);
            now = eta;
            net.complete(f);
            net.recompute();
            t = now.secs_f64();
        }
        assert!(t > 0.0, "seed {seed}: nothing ran");
        assert_eq!(net.active_count(), 0, "seed {seed}: drain incomplete");
    }
}

#[test]
fn draining_everything_moves_all_bytes() {
    // Event-loop style drain: every flow completes, in finite steps,
    // with monotone time.
    for seed in 0..100 {
        let (mut net, _, flows) = random_net(3000 + seed);
        let mut now = SimTime::ZERO;
        let mut steps = 0;
        while let Some((eta, f)) = net.next_completion(now) {
            assert!(eta >= now, "seed {seed}: time went backwards");
            net.advance(eta - now);
            now = eta;
            net.complete(f);
            net.recompute();
            steps += 1;
            assert!(steps <= flows.len() + 1, "seed {seed}: too many completions");
        }
        for f in &flows {
            // Either done or genuinely starved (zero-capacity path).
            if !net.is_done(*f) {
                assert_eq!(net.rate_each(*f), 0.0, "seed {seed}: live flow stalled");
            }
        }
    }
}

#[test]
fn fairness_pareto_property() {
    // Max-min: no flow can be rate-increased without decreasing a flow
    // of equal-or-smaller rate. Spot-check: on every saturated link the
    // unfrozen flows share equally (all capped/remote-bottlenecked
    // flows get less, never more).
    for seed in 0..100 {
        let mut rng = Pcg64::new(7000 + seed);
        let mut net = FlowNet::new();
        let cap = rng.range_f64(1e9, 1e10);
        let l = net.add_link("l", Capacity::Fixed(cap));
        let n = 2 + rng.below(10);
        let mut fl = Vec::new();
        for _ in 0..n {
            let rate_cap = if rng.f64() < 0.4 {
                rng.range_f64(1e6, 1e9)
            } else {
                f64::INFINITY
            };
            fl.push((net.start_capped(vec![l], 1, 1 << 30, rate_cap), rate_cap));
        }
        net.recompute();
        let uncapped_rates: Vec<f64> = fl
            .iter()
            .filter(|(_, c)| c.is_infinite())
            .map(|(f, _)| net.rate_each(*f))
            .collect();
        if uncapped_rates.len() >= 2 {
            let first = uncapped_rates[0];
            for r in &uncapped_rates {
                assert!(
                    (r - first).abs() < first * 1e-9,
                    "seed {seed}: unequal uncapped shares {uncapped_rates:?}"
                );
            }
        }
        // Capped flows never exceed their cap, and never exceed the
        // fair share of uncapped flows.
        for (f, c) in &fl {
            let r = net.rate_each(*f);
            assert!(r <= c * (1.0 + 1e-9), "seed {seed}: cap violated");
            if let Some(&u) = uncapped_rates.first() {
                assert!(r <= u * (1.0 + 1e-9), "seed {seed}: capped flow beat fair share");
            }
        }
    }
}

#[test]
fn plan_executor_respects_critical_path() {
    // Random DAG plans: measured completion >= critical path and
    // >= the bandwidth lower bound of their flows.
    use xstage::engine::SimCore;
    use xstage::simtime::plan::Plan;
    for seed in 0..50 {
        let mut rng = Pcg64::new(9000 + seed);
        let mut core = SimCore::new();
        let l = core.net.add_link("l", Capacity::Fixed(1e9));
        let mut p = Plan::new(0);
        let nsteps = 2 + rng.below(30) as usize;
        let mut ids = Vec::new();
        let mut finish = vec![0u64; nsteps];
        for i in 0..nsteps {
            let deps: Vec<_> = ids
                .iter()
                .copied()
                .filter(|_| rng.f64() < 0.2)
                .collect();
            let dur_ns = rng.below(3_000_000_000);
            let start = deps
                .iter()
                .map(|d: &xstage::simtime::plan::StepId| finish[d.0])
                .max()
                .unwrap_or(0);
            let id = if rng.f64() < 0.5 {
                p.delay(Duration(dur_ns), deps, "d")
            } else {
                // flow of dur_ns bytes at 1e9 B/s (alone: dur_ns ns).
                p.flow(vec![l], 1, dur_ns.max(1), deps, "f")
            };
            finish[i] = start + dur_ns.max(1);
            ids.push(id);
        }
        let critical = *finish.iter().max().unwrap();
        core.submit(p);
        core.run_to_completion();
        assert!(
            core.now.0 >= critical,
            "seed {seed}: finished {} before critical path {critical}",
            core.now.0
        );
    }
}

// ----------------------------------------------------------------------
// Slow-vs-fast differential suite: the incremental component model must
// be behaviourally indistinguishable (within FP tolerance) from the
// global reference pass, over randomized start/advance/complete
// schedules mixing Fixed/Degrading links, per-member caps, pathless
// flows, and large bundles.
// ----------------------------------------------------------------------

fn close_rate(a: f64, b: f64) -> bool {
    if a == b {
        return true; // covers both INFINITY
    }
    (a - b).abs() <= 1e-6 + 1e-9 * a.abs().max(b.abs())
}

fn close_bytes(a: f64, b: f64) -> bool {
    (a - b).abs() <= 1.0 + 1e-9 * a.abs().max(b.abs())
}

/// Drive one randomized schedule through both models in lockstep;
/// returns the number of completions exercised.
fn differential_schedule(seed: u64, ops: usize) -> usize {
    let mut rng = Pcg64::new(seed);
    let mut slow = FlowNet::with_mode(ThroughputMode::Slow);
    let mut fast = FlowNet::with_mode(ThroughputMode::Fast);
    let nlinks = 2 + rng.below(8) as usize;
    let mut links = Vec::with_capacity(nlinks);
    for i in 0..nlinks {
        let peak = rng.range_f64(1e8, 1e11);
        let cap = if rng.f64() < 0.3 {
            Capacity::Degrading {
                peak,
                pivot: rng.range_f64(1.0, 1e4),
                half: rng.range_f64(10.0, 1e4),
            }
        } else {
            Capacity::Fixed(peak)
        };
        let a = slow.add_link(format!("l{i}"), cap);
        let b = fast.add_link(format!("l{i}"), cap);
        assert_eq!(a, b);
        links.push(a);
    }

    let mut live: Vec<(FlowId, f64)> = Vec::new();
    let mut now = SimTime::ZERO;
    let mut completions = 0usize;
    for _ in 0..ops {
        let r = rng.f64();
        if r < 0.45 || live.is_empty() {
            // Start a flow: pathless 10%, capped 30%, bundled members.
            let path = if rng.f64() < 0.1 {
                vec![]
            } else {
                let plen = 1 + rng.below((nlinks as u64).min(3)) as usize;
                let mut p: Vec<LinkId> = Vec::new();
                for _ in 0..plen {
                    let l = links[rng.below(nlinks as u64) as usize];
                    if !p.contains(&l) {
                        p.push(l);
                    }
                }
                p
            };
            let members = 1 + rng.below(10_000);
            let bytes = 1 + rng.below(1 << 32);
            let cap = if rng.f64() < 0.3 {
                rng.range_f64(1e6, 1e10)
            } else {
                f64::INFINITY
            };
            let a = slow.start_capped(path.clone(), members, bytes, cap);
            let b = fast.start_capped(path, members, bytes, cap);
            assert_eq!(a, b, "seed {seed}: slab id divergence");
            live.push((a, bytes as f64));
        } else if r < 0.70 {
            // Advance virtual time without any rate change.
            let dt = Duration::from_secs_f64(rng.range_f64(0.0, 2.0));
            slow.advance(dt);
            fast.advance(dt);
            now += dt;
        } else {
            // Complete the oracle's next completion on both models.
            slow.recompute();
            fast.recompute();
            let Some((t_slow, f)) = slow.next_completion(now) else { continue };
            let Some((t_fast, _)) = fast.next_completion(now) else {
                // A flow whose fair share cancels to ~0 can land on
                // either side of exact 0.0 between the two summation
                // orders: one model calls it starved, the other gives
                // it an astronomically distant ETA. Anything nearer
                // than that is a genuine divergence.
                assert!(
                    (t_slow - now).secs_f64() > 1e9,
                    "seed {seed}: fast model starved while slow expects completion at {t_slow:?}"
                );
                continue;
            };
            let (es, ef) = ((t_slow - now).secs_f64(), (t_fast - now).secs_f64());
            assert!(
                (es - ef).abs() <= 1e-9 + 1e-9 * es.max(1.0),
                "seed {seed}: completion ETA diverged: slow {es} vs fast {ef}"
            );
            let dt = t_slow - now;
            slow.advance(dt);
            fast.advance(dt);
            now = t_slow;
            // Instantaneous (infinite-rate) flows report ETA 0 with
            // their bytes still unmaterialised; everything else must
            // be drained to FP residue in both models.
            assert!(
                fast.rate_each(f) == f64::INFINITY || fast.remaining_each(f) <= 16.0,
                "seed {seed}: fast model disagrees that {f:?} drained \
                 ({} bytes left)",
                fast.remaining_each(f)
            );
            slow.complete(f);
            fast.complete(f);
            live.retain(|(id, _)| *id != f);
            completions += 1;
        }
        // After every operation: settle both and compare all visible
        // per-flow state.
        slow.recompute();
        fast.recompute();
        for &(f, bytes) in &live {
            let (rs, rf) = (slow.rate_each(f), fast.rate_each(f));
            assert!(
                close_rate(rs, rf),
                "seed {seed}: rate diverged for {f:?} ({bytes} B): slow {rs} vs fast {rf}"
            );
            let (ms, mf) = (slow.remaining_each(f), fast.remaining_each(f));
            assert!(
                close_bytes(ms, mf),
                "seed {seed}: remaining diverged for {f:?}: slow {ms} vs fast {mf}"
            );
            assert_eq!(slow.is_done(f), fast.is_done(f), "seed {seed}: liveness diverged");
        }
        assert_eq!(
            slow.active_count(),
            fast.active_count(),
            "seed {seed}: active set sizes diverged"
        );
    }
    completions
}

#[test]
fn slow_vs_fast_equivalence_1000_schedules() {
    // >= 1000 randomized schedules (acceptance floor; CI pins the
    // count — locally `XSTAGE_PROP_SCHEDULES` scales it); every op
    // compares the full visible state of both models.
    let mut total_completions = 0usize;
    // This suite's acceptance floor is 2x the other property suites'
    // (1000 schedules at the 500-schedule default/CI pin).
    let n = 2 * xstage::util::prop_schedules(500);
    for seed in 0..n {
        total_completions += differential_schedule(0xD1FF_0000 + seed, 40);
    }
    // Sanity: the suite actually exercised the completion path a lot
    // (two completions per schedule on average).
    assert!(
        total_completions as u64 > 2 * n,
        "differential suite barely completed anything: {total_completions}"
    );
}

#[test]
fn slow_vs_fast_full_drain_agrees() {
    // Drain entire random networks through both models, completing the
    // oracle's pick each step: total drain times must agree.
    for seed in 0..100u64 {
        let mut rng = Pcg64::new(0xABCD + seed);
        let mut slow = FlowNet::with_mode(ThroughputMode::Slow);
        let mut fast = FlowNet::with_mode(ThroughputMode::Fast);
        let nlinks = 2 + rng.below(5) as usize;
        let links: Vec<LinkId> = (0..nlinks)
            .map(|i| {
                let cap = Capacity::Fixed(rng.range_f64(1e8, 1e10));
                let a = slow.add_link(format!("l{i}"), cap);
                let b = fast.add_link(format!("l{i}"), cap);
                assert_eq!(a, b);
                a
            })
            .collect();
        for _ in 0..(1 + rng.below(20)) {
            let l1 = links[rng.below(nlinks as u64) as usize];
            let l2 = links[rng.below(nlinks as u64) as usize];
            let path = if l1 == l2 { vec![l1] } else { vec![l1, l2] };
            let members = 1 + rng.below(2_000);
            let bytes = 1 + rng.below(1 << 30);
            slow.start(path.clone(), members, bytes);
            fast.start(path, members, bytes);
        }
        slow.recompute();
        fast.recompute();
        let mut now = SimTime::ZERO;
        while let Some((eta, f)) = slow.next_completion(now) {
            let dt = eta - now;
            slow.advance(dt);
            fast.advance(dt);
            now = eta;
            slow.complete(f);
            fast.complete(f);
            slow.recompute();
            fast.recompute();
        }
        assert_eq!(fast.active_count(), 0, "seed {seed}: fast model left flows behind");
    }
}
