//! Property suite for the elastic multi-tenant serving layer.
//!
//! Three randomized families, `XSTAGE_PROP_SCHEDULES` schedules each
//! (default 500; CI pins it explicitly):
//!
//! - **Starvation-freedom**: random multi-tenant workloads — random
//!   weight vectors, keep-alive/prewarm policies, tight budgets, and
//!   (sometimes) elastic pool churn — must serve every session with a
//!   finite admission wait, admit each session exactly once, and
//!   replay bit-identically.
//! - **Weighted-fairness bound**: two tenants dump a simultaneous
//!   backlog of equal-sized working sets through a one-working-set
//!   budget. Over every admission prefix where both tenants are still
//!   backlogged, no tenant's admitted-bytes share may deviate from its
//!   weight share by more than one max-session working set (checked in
//!   exact integer form).
//! - **Seed-FIFO identity**: equal weights with policies off (and a
//!   zero-event elastic pool) must replay the single-tenant seed
//!   service bit-for-bit, under both flow-network throughput models.

use xstage::simtime::flownet::ThroughputMode;
use xstage::staging::service::{
    run_serve, run_serve_specs, Batch, BatchKind, ServiceCfg, SessionSpec,
};
use xstage::staging::{ElasticCfg, PolicyKind, TenantsCfg};
use xstage::units::{SimTime, MB};
use xstage::util::prng::Pcg64;
use xstage::util::prop_schedules;

// ---------------------------------------------------------------------
// Family 1: starvation-freedom under random multi-tenant schedules
// ---------------------------------------------------------------------

fn random_cfg(rng: &mut Pcg64) -> ServiceCfg {
    let tenants = rng.range_u64(1, 3) as usize;
    let weights: Vec<u32> = (0..tenants).map(|_| rng.range_u64(1, 4) as u32).collect();
    let files = rng.range_u64(2, 4) as usize;
    let file_bytes = rng.range_u64(2, 8) * MB;
    let ds = files as u64 * file_bytes;
    let policy = match rng.range_u64(0, 2) {
        0 => PolicyKind::None,
        1 => PolicyKind::FixedKeepAlive(rng.range_u64(30, 300) as f64),
        _ => PolicyKind::Adaptive { default_keepalive_secs: 120.0, max_keepalive_secs: 600.0 },
    };
    // The elastic floor: 4 nodes, min 2 warm, budget >= 2 working
    // sets, so even the smallest pool retains budget for one set.
    let elastic = (rng.f64() < 0.4).then(|| ElasticCfg {
        seed: rng.next_u64(),
        events: rng.range_u64(1, 8) as usize,
        mean_gap_secs: rng.log_uniform(20.0, 120.0),
        min_nodes: 2,
        warmup_secs: rng.log_uniform(5.0, 60.0),
    });
    ServiceCfg {
        seed: rng.next_u64(),
        sessions: rng.range_u64(3, 9) as usize,
        mean_gap_secs: rng.log_uniform(5.0, 40.0),
        datasets: rng.range_u64(2, 4) as usize,
        files_per_dataset: files,
        file_bytes,
        ramdisk_slice: Some(rng.range_u64(2, 3) * ds),
        ssd_slice: if rng.f64() < 0.5 { Some(0) } else { None },
        tenants: TenantsCfg { weights },
        policy,
        elastic,
        ..Default::default()
    }
}

#[test]
fn every_queued_session_is_admitted_on_random_multi_tenant_schedules() {
    for seed in 0..prop_schedules(500) {
        let mut rng = Pcg64::new(0xE1A0 ^ seed);
        let cfg = random_cfg(&mut rng);
        let out = run_serve(4, &cfg, ThroughputMode::Fast);
        // Starvation-freedom: every session served, every admission
        // wait finite and inside the run.
        assert_eq!(out.turnaround_secs.len(), cfg.sessions, "seed {seed}");
        assert_eq!(out.admission_order.len(), cfg.sessions, "seed {seed}");
        assert!(
            out.admit_wait_secs
                .iter()
                .all(|w| w.is_finite() && *w >= 0.0 && *w <= out.virtual_secs),
            "a session waited unbounded (seed {seed})"
        );
        // Admitted exactly once each.
        let mut seen = vec![false; cfg.sessions];
        for &s in &out.admission_order {
            assert!(!seen[s], "session {s} admitted twice (seed {seed})");
            seen[s] = true;
        }
        // Attribution closes: every staged byte belongs to a tenant.
        assert_eq!(
            out.tenant_gpfs_bytes.iter().sum::<u64>(),
            out.staged_bytes,
            "seed {seed}"
        );
        // Bit-identical replay, policies and churn included.
        let again = run_serve(4, &cfg, ThroughputMode::Fast);
        assert_eq!(out.turnaround_secs, again.turnaround_secs, "seed {seed}");
        assert_eq!(out.admission_order, again.admission_order, "seed {seed}");
        assert_eq!(out.warm_hits, again.warm_hits, "seed {seed}");
        assert_eq!(out.pool_events, again.pool_events, "seed {seed}");
    }
}

// ---------------------------------------------------------------------
// Family 2: the weighted-fairness bound on simultaneous backlogs
// ---------------------------------------------------------------------

#[test]
fn weighted_fairness_bound_holds_on_random_two_tenant_backlogs() {
    for seed in 0..prop_schedules(500) {
        let mut rng = Pcg64::new(0xFA12 ^ seed);
        let (w0, w1) = (rng.range_u64(1, 4) as u32, rng.range_u64(1, 4) as u32);
        let (n0, n1) = (rng.range_u64(2, 6), rng.range_u64(2, 6));
        let sessions = (n0 + n1) as usize;
        // Interleave the two backlogs; every session gets its own
        // equal-sized dataset so each admission charges exactly one
        // working set.
        let mut left = [n0, n1];
        let specs: Vec<SessionSpec> = (0..sessions)
            .map(|i| {
                let mut t = i % 2;
                if left[t] == 0 {
                    t ^= 1;
                }
                left[t] -= 1;
                SessionSpec {
                    arrival: SimTime::ZERO,
                    dataset: i,
                    tenant: t,
                    batches: vec![Batch {
                        kind: BatchKind::Nf,
                        tasks: rng.range_u64(1, 6) as usize,
                    }],
                }
            })
            .collect();
        let ds = 3 * 4 * MB;
        let cfg = ServiceCfg {
            seed: rng.next_u64(),
            sessions,
            datasets: sessions,
            files_per_dataset: 3,
            file_bytes: 4 * MB,
            // One working set of budget: admissions are serial, so
            // every slot is a fresh weighted pick over the backlog.
            ramdisk_slice: Some(ds),
            ssd_slice: Some(0),
            tenants: TenantsCfg { weights: vec![w0, w1] },
            ..Default::default()
        };
        let out = run_serve_specs(2, &cfg, ThroughputMode::Fast, specs.clone());
        assert_eq!(out.admission_order.len(), sessions, "seed {seed}");
        // Exact integer form of the bound: with equal working sets,
        // "admitted-bytes share deviates from weight share by at most
        // one max-session working set" is
        //   |served_T - k*ds*w_T/W| <= ds  <=>  |c0*w1 - c1*w0| <= max(w)
        // over every prefix (length k, c_T admissions to tenant T)
        // while both tenants are still backlogged.
        let (mut c0, mut c1) = (0u64, 0u64);
        for &s in &out.admission_order {
            if c0 == n0 || c1 == n1 {
                break; // one backlog drained: picks are forced now
            }
            if specs[s].tenant == 0 {
                c0 += 1;
            } else {
                c1 += 1;
            }
            let dev = (c0 * w1 as u64).abs_diff(c1 * w0 as u64);
            assert!(
                dev <= w0.max(w1) as u64,
                "fairness bound broken (seed {seed}, weights {w0}:{w1}, \
                 counts {c0}:{c1}, dev {dev})"
            );
        }
        // And nobody starves even when the weights are lopsided.
        assert!(out.admit_wait_secs.iter().all(|w| w.is_finite()), "seed {seed}");
    }
}

// ---------------------------------------------------------------------
// Family 3: equal weights + policies off replay the seed FIFO
// ---------------------------------------------------------------------

#[test]
fn equal_weights_and_policies_off_replay_the_seed_fifo_bit_identically() {
    for seed in 0..prop_schedules(500) {
        let mut rng = Pcg64::new(0x5EED ^ seed);
        let files = rng.range_u64(2, 5) as usize;
        let file_bytes = rng.range_u64(2, 8) * MB;
        let ds = files as u64 * file_bytes;
        let base = ServiceCfg {
            seed: rng.next_u64(),
            sessions: rng.range_u64(2, 8) as usize,
            mean_gap_secs: rng.log_uniform(5.0, 40.0),
            datasets: rng.range_u64(2, 4) as usize,
            files_per_dataset: files,
            file_bytes,
            ramdisk_slice: Some(rng.range_u64(1, 2) * ds),
            ssd_slice: if rng.f64() < 0.5 { Some(0) } else { None },
            ..Default::default()
        };
        let mut tenanted = base.clone();
        let count = rng.range_u64(1, 3) as usize;
        tenanted.tenants = TenantsCfg { weights: vec![rng.range_u64(1, 4) as u32; count] };
        tenanted.policy = PolicyKind::None;
        // A zero-event pool must disarm entirely (rule E4).
        tenanted.elastic = Some(ElasticCfg { events: 0, ..Default::default() });
        for mode in [ThroughputMode::Fast, ThroughputMode::Slow] {
            let a = run_serve(3, &base, mode);
            let b = run_serve(3, &tenanted, mode);
            assert_eq!(a.turnaround_secs, b.turnaround_secs, "seed {seed} {mode:?}");
            assert_eq!(a.virtual_secs, b.virtual_secs, "seed {seed} {mode:?}");
            assert_eq!(a.staged_bytes, b.staged_bytes, "seed {seed} {mode:?}");
            assert_eq!(a.promoted_bytes, b.promoted_bytes, "seed {seed} {mode:?}");
            assert_eq!(a.demoted_bytes, b.demoted_bytes, "seed {seed} {mode:?}");
            assert_eq!(a.peak_queue, b.peak_queue, "seed {seed} {mode:?}");
            assert_eq!(a.admission_order, b.admission_order, "seed {seed} {mode:?}");
            assert_eq!(b.warm_hits, 0, "seed {seed} {mode:?}");
            assert_eq!(b.pool_events, 0, "seed {seed} {mode:?}");
        }
    }
}
