//! Integration tests for the interactive serving layer: determinism
//! of seeded serve runs, the single-session bit-identity property of
//! session-fair scheduling, and end-to-end serving behaviour.

use xstage::cluster::{orthros, Topology};
use xstage::dataflow::graph::{Task, TaskGraph};
use xstage::dataflow::sched::{run_workflow, SchedulerCfg, SessionScheduler};
use xstage::engine::SimCore;
use xstage::mpisim::Comm;
use xstage::pfs::{Blob, GpfsParams};
use xstage::simtime::flownet::ThroughputMode;
use xstage::staging::service::{run_serve, ServeMode, ServiceCfg};
use xstage::units::{Duration, MB};
use xstage::util::prng::Pcg64;

fn serve_cfg(mode: ServeMode, seed: u64) -> ServiceCfg {
    ServiceCfg {
        seed,
        sessions: 12,
        mean_gap_secs: 25.0,
        datasets: 3,
        files_per_dataset: 5,
        file_bytes: 10 * MB,
        mode,
        ..Default::default()
    }
}

#[test]
fn seeded_serve_runs_are_bit_identical() {
    // The acceptance-bar determinism property: two identical seeded
    // serve runs produce bit-identical session turnaround tables —
    // f64 seconds derived from integer nanoseconds, compared exactly.
    for mode in [ServeMode::Staged, ServeMode::Naive] {
        let a = run_serve(2, &serve_cfg(mode, 1234), ThroughputMode::Fast);
        let b = run_serve(2, &serve_cfg(mode, 1234), ThroughputMode::Fast);
        assert_eq!(a.turnaround_secs, b.turnaround_secs, "mode {mode:?}");
        assert_eq!(a.percentiles, b.percentiles);
        assert_eq!(a.staged_bytes, b.staged_bytes);
        assert_eq!(a.virtual_secs, b.virtual_secs);
    }
    // A different seed genuinely changes the workload.
    let a = run_serve(2, &serve_cfg(ServeMode::Staged, 1234), ThroughputMode::Fast);
    let c = run_serve(2, &serve_cfg(ServeMode::Staged, 99), ThroughputMode::Fast);
    assert_ne!(a.turnaround_secs, c.turnaround_secs);
}

/// Random task graph mixing short/long tasks, staged + shared inputs.
fn mixed_graph(seed: u64, n: usize) -> TaskGraph {
    let mut g = TaskGraph::new();
    let mut rng = Pcg64::new(seed);
    g.foreach(n, |i| {
        let mut t = Task::compute(
            format!("t{i}"),
            Duration::from_secs_f64(rng.log_uniform(1.0, 25.0)),
        );
        if i % 3 == 0 {
            t = t.with_input("/tmp/d/in.bin", None);
        }
        if i % 5 == 0 {
            t = t.with_input("/data/shared.bin", None).with_output(MB / 4);
        }
        t
    });
    g
}

#[test]
fn session_fair_with_one_session_is_bit_identical_to_scheduler() {
    // The property check from the issue: session-fair scheduling with
    // exactly one session must be indistinguishable from the existing
    // scheduler — completion times, final clock, and byte accounting
    // all bit-identical, across cfg variants and both throughput
    // models.
    for mode in [ThroughputMode::Fast, ThroughputMode::Slow] {
        for cfg in [
            SchedulerCfg::default(),
            SchedulerCfg { locality_aware: true, ..Default::default() },
            SchedulerCfg { cache_inputs: true, locality_aware: true, ..Default::default() },
        ] {
            let build = || {
                let mut core = SimCore::with_mode(mode);
                let topo = Topology::build(orthros(), GpfsParams::default(), &mut core.net);
                let comm = Comm::world(&topo.spec);
                core.pfs.write("/data/shared.bin", Blob::synthetic(20 * MB, 8));
                core.pfs.write("/tmp/d/in.bin", Blob::synthetic(30 * MB, 9));
                core.node_write_range(0, 2, "/tmp/d/in.bin", Blob::synthetic(30 * MB, 9));
                (core, topo, comm)
            };
            let (mut core_a, topo_a, comm_a) = build();
            let base = run_workflow(&mut core_a, &topo_a, &comm_a, mixed_graph(5, 400), cfg);
            let (mut core_b, topo_b, comm_b) = build();
            let mut ss = SessionScheduler::new(topo_b, comm_b, cfg);
            let sid = ss.add_session(&mut core_b, mixed_graph(5, 400));
            core_b.run(&mut ss);
            let s = ss.stats(sid);
            assert_eq!(base.completion, s.completion);
            assert_eq!(core_a.now, core_b.now);
            assert_eq!(base.staged_read_bytes, s.reads.staged_bytes);
            assert_eq!(base.unstaged_read_bytes, s.reads.unstaged_bytes);
            assert_eq!(base.cache_hits, s.reads.cache_hits);
            assert_eq!(core_a.events_processed, core_b.events_processed);
        }
    }
}

#[test]
fn new_admission_path_replays_the_baseline_serve_outcome_bit_identically() {
    // Differential regression guarding the weighted-admission
    // refactor: the single-tenant baseline run must be reproduced
    // bit-for-bit when the same workload is routed through the
    // weighted pick (two equal-weight tenants, policies off) —
    // turnaround samples, GPFS bytes, and the queue high-water mark.
    for mode in [ServeMode::Staged, ServeMode::Naive] {
        let baseline = run_serve(2, &serve_cfg(mode, 1234), ThroughputMode::Fast);
        let mut cfg = serve_cfg(mode, 1234);
        cfg.tenants = xstage::staging::TenantsCfg { weights: vec![2, 2] };
        cfg.policy = xstage::staging::PolicyKind::None;
        let new = run_serve(2, &cfg, ThroughputMode::Fast);
        assert_eq!(baseline.turnaround_secs, new.turnaround_secs, "mode {mode:?}");
        assert_eq!(baseline.percentiles, new.percentiles, "mode {mode:?}");
        assert_eq!(baseline.virtual_secs, new.virtual_secs, "mode {mode:?}");
        assert_eq!(baseline.staged_bytes, new.staged_bytes, "mode {mode:?}");
        assert_eq!(baseline.promoted_bytes, new.promoted_bytes, "mode {mode:?}");
        assert_eq!(baseline.demoted_bytes, new.demoted_bytes, "mode {mode:?}");
        assert_eq!(baseline.reads, new.reads, "mode {mode:?}");
        assert_eq!(baseline.peak_queue, new.peak_queue, "mode {mode:?}");
        assert_eq!(baseline.admission_order, new.admission_order, "mode {mode:?}");
        // The new counters stay inert on the seed path.
        assert_eq!(new.warm_hits, 0);
        assert_eq!(new.keepalive_grants, 0);
        assert_eq!(new.pool_events, 0);
    }
}

#[test]
fn staged_serving_beats_naive_p99_end_to_end() {
    let s = run_serve(2, &serve_cfg(ServeMode::Staged, 7), ThroughputMode::Fast);
    let n = run_serve(2, &serve_cfg(ServeMode::Naive, 7), ThroughputMode::Fast);
    let (sp, np) = (s.percentiles.unwrap(), n.percentiles.unwrap());
    assert!(sp.p99 < np.p99, "staged P99 {} vs naive P99 {}", sp.p99, np.p99);
    // Staged serving moved each dataset at most once (residency hits
    // absorb re-opens) while naive re-read from GPFS per task.
    assert!(s.staged_bytes <= 3 * 5 * 10 * MB);
    assert!(n.reads.unstaged_bytes > n.sessions as u64 * 5 * 10 * MB);
    assert_eq!(s.reads.unstaged_bytes, 0);
}

#[test]
fn serving_engine_reclaims_finished_plan_storage() {
    // The engine change that makes long-running serving viable: after
    // the run drains, no step descriptors remain live even though
    // hundreds of per-task plans were submitted over the session.
    let cfg = serve_cfg(ServeMode::Staged, 3);
    let mut core = SimCore::new();
    let mut spec = orthros();
    spec.nodes = 2;
    let topo = Topology::build(spec, GpfsParams::default(), &mut core.net);
    let comm = Comm::world(&topo.spec);
    core.pfs.write("/tmp/x/in.bin", Blob::synthetic(MB, 1));
    let mut ss = SessionScheduler::new(topo, comm, cfg.sched);
    let mut g = TaskGraph::new();
    g.foreach(300, |i| Task::compute(format!("t{i}"), Duration::from_secs(1)));
    ss.add_session(&mut core, g);
    core.run(&mut ss);
    assert!(ss.all_done());
    assert_eq!(core.live_plans(), 0);
    assert_eq!(core.retained_steps(), 0);
}
