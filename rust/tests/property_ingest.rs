//! Property suite for the streaming-ingest path and the tiered node
//! store underneath it, over randomized schedules:
//!
//! - **Detector schedules** — 500 random ingest configurations driven
//!   through the real event loop: every emitted frame lands in exactly
//!   one tier (nothing lost, nothing duplicated), the spill order is
//!   monotone down the RAM -> SSD -> GPFS ladder, landed content
//!   verifies bit-for-bit, the catalog grows to exactly the stream
//!   size, no tier ever exceeds its capacity, and the whole run
//!   replays bit-identically under both throughput models.
//! - **Store op sequences** — 500 random interleavings of RAM writes,
//!   direct SSD writes, pins, and unpins: per-tier capacity is never
//!   exceeded, pinned replicas are never displaced, and a `Rejected`
//!   write leaves both tiers byte-for-byte untouched.

use xstage::catalog::Catalog;
use xstage::cluster::{orthros, NodeStores, Topology};
use xstage::engine::{Director, Notice, SimCore};
use xstage::pfs::{Blob, GpfsParams};
use xstage::simtime::flownet::ThroughputMode;
use xstage::staging::ingest::{Ingest, IngestCfg, IngestMode, INGEST_TAG_BASE};
use xstage::storage::{StorageTier, StoreWrite};
use xstage::units::MB;
use xstage::util::prng::Pcg64;

/// Schedule count: `XSTAGE_PROP_SCHEDULES` if set, else 500.
fn schedules() -> u64 {
    xstage::util::prop_schedules(500)
}

/// Forwards ingest-tagged notices to the detector, exactly as the
/// serving director does.
struct Drive {
    topo: Topology,
    catalog: Catalog,
    ing: Ingest,
}

impl Director for Drive {
    fn on_notice(&mut self, core: &mut SimCore, notice: Notice) {
        match notice {
            Notice::Timer { tag } if tag >= INGEST_TAG_BASE => {
                self.ing.on_timer(core, &self.topo);
            }
            Notice::PlanDone { tag, .. } if tag >= INGEST_TAG_BASE => {
                self.ing.on_plan_done(core, &self.topo, &mut self.catalog, tag);
            }
            _ => {}
        }
    }
}

/// Run one detector schedule to completion on a 2-node Orthros slice.
fn run_ingest(
    cfg: IngestCfg,
    ram_cap: u64,
    ssd_cap: Option<u64>,
    mode: ThroughputMode,
) -> (SimCore, Drive) {
    let mut core = SimCore::with_mode(mode);
    let mut machine = orthros();
    machine.nodes = 2;
    let topo = Topology::build(machine, GpfsParams::default(), &mut core.net);
    core.nodes.set_capacity(Some(ram_cap));
    core.nodes.set_ssd_capacity(ssd_cap);
    let mut catalog = Catalog::new();
    let id = catalog.register("live", "/projects/serve/ds0", 0, 0);
    let mut ing = Ingest::new(cfg, id);
    ing.start(&mut core);
    let mut d = Drive { topo, catalog, ing };
    core.run(&mut d);
    (core, d)
}

#[test]
fn random_detector_schedules_conserve_frames_and_replay() {
    let mut rng = Pcg64::new(0x1A6E57_600D);
    for schedule in 0..schedules() {
        let frames = 1 + rng.below(8) as usize;
        let frame_bytes = (1 + rng.below(3)) * MB;
        let total = frames as u64 * frame_bytes;
        let cfg = IngestCfg {
            seed: rng.below(u64::MAX),
            frames,
            frame_bytes,
            frame_gap_secs: 0.02 + 0.48 * rng.f64(),
            buffer_frames: 1 + rng.below(4) as usize,
            // 0..=total in whole frames: sweeps all-RAM, mixed, and
            // nothing-fits regimes.
            ram_slice: rng.below(frames as u64 + 1) * frame_bytes,
            dataset: 0,
            mode: IngestMode::Stream,
        };
        // The store itself always has room for the slice; the slice is
        // the binding RAM constraint, as in the serving layer.
        let ram_cap = total + MB;
        let ssd_cap = match rng.below(3) {
            0 => None,
            _ => Some(rng.below(frames as u64 + 1) * frame_bytes),
        };
        let (core, d) = run_ingest(cfg.clone(), ram_cap, ssd_cap, ThroughputMode::Fast);
        let ctx = format!("schedule {schedule}: {cfg:?} ssd {ssd_cap:?}");

        // Conservation: every frame landed in exactly one tier.
        assert!(d.ing.complete(), "{ctx}");
        let tiers: Vec<StorageTier> =
            d.ing.frame_tiers().iter().map(|t| t.expect("unlanded frame")).collect();
        assert_eq!(tiers.len(), frames, "{ctx}");
        let out = d.ing.outcome(None);
        assert_eq!(out.ram_frames + out.ssd_frames + out.gpfs_frames, frames, "{ctx}");

        // Spill order is monotone down the ladder (`StorageTier` is
        // declared in ladder order): frames are all the same size and
        // landed replicas are pinned, so once a tier rejects it stays
        // rejected.
        for w in tiers.windows(2) {
            assert!(w[0] <= w[1], "{ctx}: tiers {tiers:?}");
        }

        // Capacity: the RAM slice and each tier budget are honored.
        assert!(out.ram_frames as u64 * frame_bytes <= cfg.ram_slice, "{ctx}");
        for node in 0..2 {
            assert!(core.nodes.bytes_on(node) <= ram_cap, "{ctx}");
            let ssd = core.nodes.bytes_on_tier(StorageTier::Ssd, node);
            match ssd_cap {
                Some(cap) => assert!(ssd <= cap, "{ctx}: ssd {ssd} > {cap}"),
                None => assert_eq!(ssd, 0, "{ctx}"),
            }
        }

        // Content verifies where the detector says it landed, and the
        // catalog saw every frame exactly once.
        d.ing.verify(&core, &d.topo);
        let rec = d.catalog.get(d.ing.dataset_id()).unwrap();
        assert_eq!((rec.files, rec.bytes), (frames as u64, total), "{ctx}");

        // Bit-identical replay under both throughput models.
        for mode in [ThroughputMode::Fast, ThroughputMode::Slow] {
            let (ca, da) = run_ingest(cfg.clone(), ram_cap, ssd_cap, mode);
            let (cb, db) = run_ingest(cfg.clone(), ram_cap, ssd_cap, mode);
            assert_eq!(da.ing.frame_tiers(), db.ing.frame_tiers(), "{ctx} {mode:?}");
            assert_eq!(da.ing.stalls(), db.ing.stalls(), "{ctx} {mode:?}");
            assert_eq!(ca.now, cb.now, "{ctx} {mode:?}");
            assert_eq!(ca.events_processed, cb.events_processed, "{ctx} {mode:?}");
        }
    }
}

#[test]
fn random_store_sequences_respect_caps_pins_and_rejection() {
    const NODES: u32 = 3;
    let snapshot = |ns: &NodeStores| {
        (ns.dump_tier(StorageTier::Ram), ns.dump_tier(StorageTier::Ssd))
    };
    let mut rng = Pcg64::new(0x570E_600D);
    for schedule in 0..schedules() {
        let mut ns = NodeStores::new();
        let ram_cap = (1 + rng.below(8)) * MB;
        let ssd_cap = match rng.below(4) {
            0 => None,
            _ => Some((1 + rng.below(8)) * MB),
        };
        ns.set_capacity(Some(ram_cap));
        ns.set_ssd_capacity(ssd_cap);
        let mut pinned: Vec<String> = Vec::new();
        for op in 0..30u64 {
            let path = format!("/tmp/p{}.bin", rng.below(6));
            let lo = rng.below(NODES as u64) as u32;
            let hi = lo + rng.below(NODES as u64 - lo as u64) as u32;
            let ctx = format!("schedule {schedule} op {op} {path} {lo}..={hi}");
            match rng.below(6) {
                0 | 1 => {
                    let data = Blob::synthetic((1 + rng.below(6)) * MB / 2, op);
                    let before = snapshot(&ns);
                    match ns.write_range_evicting(lo, hi, &path, data) {
                        StoreWrite::Stored { evicted } => {
                            for e in &evicted {
                                assert!(!pinned.contains(&e.path), "{ctx}: evicted pin {e:?}");
                            }
                        }
                        StoreWrite::Rejected { short_bytes } => {
                            assert!(short_bytes > 0, "{ctx}");
                            assert_eq!(before, snapshot(&ns), "{ctx}: rejection mutated store");
                        }
                    }
                }
                2 | 3 => {
                    let data = Blob::synthetic((1 + rng.below(6)) * MB / 2, op);
                    let before = snapshot(&ns);
                    match ns.write_range_ssd_evicting(lo, hi, &path, data) {
                        StoreWrite::Stored { evicted } => {
                            assert!(ssd_cap.is_some(), "{ctx}: stored into an absent tier");
                            for e in &evicted {
                                assert_eq!(e.tier, StorageTier::Ssd, "{ctx}");
                                assert!(!e.demoted, "{ctx}: SSD discards never demote");
                                assert!(!pinned.contains(&e.path), "{ctx}: evicted pin {e:?}");
                            }
                        }
                        StoreWrite::Rejected { short_bytes } => {
                            assert!(short_bytes > 0, "{ctx}");
                            assert_eq!(before, snapshot(&ns), "{ctx}: rejection mutated store");
                        }
                    }
                }
                4 => {
                    ns.pin(path.clone());
                    if !pinned.contains(&path) {
                        pinned.push(path);
                    }
                }
                _ => {
                    ns.unpin(&path);
                    pinned.retain(|p| *p != path);
                }
            }
            for node in 0..NODES {
                assert!(ns.bytes_on(node) <= ram_cap, "{ctx}: RAM over budget");
                let ssd = ns.bytes_on_tier(StorageTier::Ssd, node);
                match ssd_cap {
                    Some(cap) => assert!(ssd <= cap, "{ctx}: SSD over budget"),
                    None => assert_eq!(ssd, 0, "{ctx}: bytes in an absent tier"),
                }
            }
            for p in &pinned {
                assert!(ns.is_pinned(p), "{ctx}: pin dropped");
            }
        }
    }
}
