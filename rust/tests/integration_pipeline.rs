//! Integration: the full HEDM numeric pipeline through the AOT
//! artifacts — detector frames in, verified grain orientations out.
//! These are the paper's scientific workflows run end to end on real
//! pixels (skipped gracefully before `make artifacts`; the native
//! fallbacks are covered by unit tests).

use xstage::hedm::ccl::{find_peaks, parse_peaks_text, peaks_to_text};
use xstage::hedm::detector::{render_dark, render_frame, Layer, NoiseModel};
use xstage::hedm::fit::{fit_orientation, ArtifactScorer, ScanCfg};
use xstage::hedm::geometry::{simulate_spots, spot_overlap, Geom, Spot};
use xstage::hedm::reduce::{dark_median_native, reduce_frame_artifact};
use xstage::runtime::Runtime;
use xstage::util::prng::Pcg64;

macro_rules! require_artifacts {
    () => {
        if !Runtime::artifacts_available() {
            eprintln!("skipping: run `make artifacts` first");
            return;
        }
    };
}

/// Render + reduce (PJRT) + CCL one grain's scan into observed spots.
fn stage1_artifact(rt: &mut Runtime, geom: &Geom, spots: &[Spot], seed: u64) -> Vec<Spot> {
    let noise = NoiseModel::default();
    let mut rng = Pcg64::new(seed);
    let darks: Vec<Vec<f32>> =
        (0..4).map(|_| render_dark(geom, &noise, &mut rng)).collect();
    let dark = dark_median_native(&darks);
    let w = 360.0 / geom.omega_steps as f64;
    let mut observed = Vec::new();
    for step in 0..geom.omega_steps {
        let frame = render_frame(spots, geom, &noise, step, &mut rng);
        let red = reduce_frame_artifact(rt, &frame, &dark).unwrap();
        if red.count == 0 {
            continue;
        }
        let omega = -180.0 + (step as f64 + 0.5) * w;
        for p in find_peaks(&red.mask, &red.sub, geom.frame, 2) {
            observed.push(Spot { u: p.u, v: p.v, omega_deg: omega });
        }
    }
    observed
}

#[test]
fn frames_to_orientation_roundtrip() {
    require_artifacts!();
    let mut rt = Runtime::load(Runtime::default_dir()).unwrap();
    let geom = Geom::from_manifest(&rt.manifest.config);
    let layer = Layer::synthesize(1, geom, 77);
    let truth = layer.grains[0].euler;

    // Stage 1: frames -> spots. Centroids must track the forward model.
    let obs = stage1_artifact(&mut rt, &geom, &layer.grains[0].spots, 7);
    assert!(
        obs.len() as f64 >= 0.85 * layer.grains[0].spots.len() as f64,
        "stage 1 recovered {}/{} spots",
        obs.len(),
        layer.grains[0].spots.len()
    );
    for o in obs.iter().take(8) {
        let nearest = layer.grains[0]
            .spots
            .iter()
            .map(|s| ((s.u - o.u).powi(2) + (s.v - o.v).powi(2)).sqrt())
            .fold(f64::INFINITY, f64::min);
        assert!(nearest < 1.5, "centroid {nearest} px from truth");
    }

    // The stage-1 text artifact round-trips.
    let peaks = find_peaks(
        &vec![1.0; 4],
        &vec![2.0; 4],
        2,
        1,
    );
    let text = peaks_to_text(&peaks, 0.0);
    assert_eq!(parse_peaks_text(&text).len(), peaks.len());

    // Stage 2: spots -> orientation, via the PJRT fit kernel.
    let fit = {
        let mut scorer = ArtifactScorer::new(&mut rt, &obs);
        fit_orientation(&mut scorer, &ScanCfg::default()).unwrap()
    };
    assert!(fit.confidence > 0.8, "confidence {}", fit.confidence);
    let overlap = spot_overlap(
        &simulate_spots(fit.euler, &geom),
        &simulate_spots(truth, &geom),
        &geom,
    );
    assert!(overlap > 0.9, "recovered pattern overlap {overlap}");
}

#[test]
fn peak_search_artifact_matches_ccl_peak_count() {
    require_artifacts!();
    let mut rt = Runtime::load(Runtime::default_dir()).unwrap();
    let n = rt.manifest.config.frame;
    // A mask+intensity with 5 well-separated blobs.
    let mut inten = vec![0f32; n * n];
    for i in 0..5 {
        xstage::hedm::detector::splat(
            &mut inten,
            n,
            60.0 + 80.0 * i as f64,
            200.0 + 30.0 * i as f64,
            500.0,
            1.5,
        );
    }
    let mask: Vec<f32> = inten.iter().map(|&v| if v > 50.0 { 1.0 } else { 0.0 }).collect();
    let outs = rt
        .call(
            "peak_search",
            &[
                xstage::runtime::TensorF32::new(vec![n, n], mask.clone()),
                xstage::runtime::TensorF32::new(vec![n, n], inten.clone()),
            ],
        )
        .unwrap();
    let artifact_peaks = outs[0].data.iter().filter(|&&v| v > 0.5).count();
    let ccl_peaks = find_peaks(&mask, &inten, n, 2).len();
    assert_eq!(ccl_peaks, 5);
    assert_eq!(artifact_peaks, 5, "peak_search artifact found {artifact_peaks}");
}

#[test]
fn two_grain_frames_index_both() {
    require_artifacts!();
    let mut rt = Runtime::load(Runtime::default_dir()).unwrap();
    let geom = Geom::from_manifest(&rt.manifest.config);
    let layer = Layer::synthesize(2, geom, 88);
    // FF mode: both grains' spots mixed on the detector.
    let all: Vec<Spot> = layer.all_spots();
    let obs = stage1_artifact(&mut rt, &geom, &all, 9);
    let cfg = xstage::hedm::ff::IndexCfg { max_grains: 4, ..Default::default() };
    let grains = xstage::hedm::ff::index_grains_artifact(&mut rt, &obs, &cfg).unwrap();
    let truth: Vec<[f64; 3]> = layer.grains.iter().map(|g| g.euler).collect();
    let recovered = xstage::hedm::ff::count_recovered(&grains, &truth, &geom);
    assert_eq!(recovered, 2, "recovered {recovered}/2 grains from mixed frames");
}
