//! Integration: the staging hook and the naive baseline against the
//! full simulated machine — data-plane equivalence, timing shape, and
//! bit-reproducibility.

use xstage::cluster::{bgq, Topology};
use xstage::engine::SimCore;
use xstage::mpisim::Comm;
use xstage::pfs::{Blob, GpfsParams};
use xstage::simtime::flownet::ThroughputMode;
use xstage::simtime::plan::Plan;
use xstage::staging::{naive_plan, read_phase, staged_plan, HookSpec};
use xstage::units::MB;

fn setup(nodes: u32) -> (SimCore, Topology, HookSpec) {
    setup_mode(nodes, ThroughputMode::Fast)
}

fn setup_mode(nodes: u32, mode: ThroughputMode) -> (SimCore, Topology, HookSpec) {
    let mut core = SimCore::with_mode(mode);
    let topo = Topology::build(bgq(nodes), GpfsParams::default(), &mut core.net);
    for i in 0..32u64 {
        core.pfs.write(
            format!("/projects/run/f{i:03}.bin"),
            Blob::synthetic(4 * MB, 0xC0FFEE + i),
        );
    }
    // Also a real-bytes file to checksum exactly.
    core.pfs.write(
        "/projects/run/params.txt",
        Blob::real((0..=255u8).cycle().take(100_000).collect()),
    );
    let spec = HookSpec::parse("broadcast to /tmp/run { /projects/run/* }").unwrap();
    (core, topo, spec)
}

#[test]
fn staged_and_naive_deliver_identical_data() {
    let run = |staged: bool| {
        let (mut core, topo, spec) = setup(32);
        let mut p = Plan::new(0);
        if staged {
            let comm = Comm::leader(&topo.spec);
            staged_plan(&mut p, &core.pfs, &topo, &comm, &spec, vec![]).unwrap();
        } else {
            let comm = Comm::world(&topo.spec);
            naive_plan(&mut p, &core.pfs, &topo, &comm, &spec, vec![]).unwrap();
        }
        core.submit(p);
        core.run_to_completion();
        core
    };
    let s = run(true);
    let n = run(false);
    // Every node holds identical content either way.
    for node in [0u32, 15, 31] {
        for i in 0..32 {
            let path = format!("/tmp/run/f{i:03}.bin");
            let a = s.nodes.read(node, &path).expect("staged replica");
            let b = n.nodes.read(node, &path).expect("naive replica");
            assert!(a.same_content(b), "{path} differs on node {node}");
        }
        let a = s.nodes.read(node, "/tmp/run/params.txt").unwrap();
        assert_eq!(
            a.to_bytes(),
            (0..=255u8).cycle().take(100_000).collect::<Vec<_>>()
        );
    }
}

#[test]
fn simulation_is_bit_reproducible() {
    let run = || {
        let (mut core, topo, spec) = setup(256);
        let leader = Comm::leader(&topo.spec);
        let world = Comm::world(&topo.spec);
        let mut p = Plan::new(0);
        let (m, done) =
            staged_plan(&mut p, &core.pfs, &topo, &leader, &spec, vec![]).unwrap();
        read_phase(&mut p, &topo, &world, m.total_bytes, vec![done]);
        core.submit(p);
        core.run_to_completion();
        (core.now, core.events_processed)
    };
    let a = run();
    let b = run();
    assert_eq!(a, b, "identical runs must produce identical clocks");
}

#[test]
fn staged_beats_naive_at_scale_but_not_small() {
    let time = |nodes: u32, staged: bool| {
        let (mut core, topo, spec) = setup(nodes);
        let mut p = Plan::new(0);
        if staged {
            let leader = Comm::leader(&topo.spec);
            let world = Comm::world(&topo.spec);
            let (m, done) =
                staged_plan(&mut p, &core.pfs, &topo, &leader, &spec, vec![]).unwrap();
            read_phase(&mut p, &topo, &world, m.total_bytes, vec![done]);
        } else {
            let comm = Comm::world(&topo.spec);
            naive_plan(&mut p, &core.pfs, &topo, &comm, &spec, vec![]).unwrap();
        }
        core.submit(p);
        core.run_to_completion();
        core.now.secs_f64()
    };
    // At 8K nodes the hook wins decisively.
    let s8k = time(8192, true);
    let n8k = time(8192, false);
    assert!(n8k > 1.5 * s8k, "at 8K: staged {s8k}, naive {n8k}");
    // At 64 nodes there is no contention to win against (naive may
    // even be faster since it skips the write+read detour).
    let s64 = time(64, true);
    let n64 = time(64, false);
    assert!(n64 < 2.0 * s64, "at 64: staged {s64}, naive {n64}");
}

#[test]
fn hook_metadata_cost_is_constant_in_ranks() {
    // The hook's glob runs once regardless of allocation size; naive
    // metadata grows with ranks.
    let meta_phase = |nodes: u32| {
        let (mut core, topo, spec) = setup(nodes);
        let leader = Comm::leader(&topo.spec);
        let mut p = Plan::new(0);
        staged_plan(&mut p, &core.pfs, &topo, &leader, &spec, vec![]).unwrap();
        core.submit(p);
        core.run_to_completion();
        core.metrics.phase_span("glob").unwrap().secs_f64()
    };
    let small = meta_phase(64);
    let large = meta_phase(4096);
    assert!((small - large).abs() < 1e-9, "glob cost must not scale: {small} vs {large}");
}

#[test]
fn throughput_models_agree_end_to_end() {
    // The component-incremental throughput model must reproduce the
    // reference (global-recompute) timings through the whole staging
    // stack: hook plan construction, MPI collectives, engine event
    // scheduling. Staged and naive pipelines, contended at 512 nodes.
    let time = |mode: ThroughputMode, staged: bool| {
        let (mut core, topo, spec) = setup_mode(512, mode);
        let mut p = Plan::new(0);
        if staged {
            let leader = Comm::leader(&topo.spec);
            let world = Comm::world(&topo.spec);
            let (m, done) =
                staged_plan(&mut p, &core.pfs, &topo, &leader, &spec, vec![]).unwrap();
            read_phase(&mut p, &topo, &world, m.total_bytes, vec![done]);
        } else {
            let comm = Comm::world(&topo.spec);
            naive_plan(&mut p, &core.pfs, &topo, &comm, &spec, vec![]).unwrap();
        }
        core.submit(p);
        core.run_to_completion();
        core.now.secs_f64()
    };
    for staged in [true, false] {
        let slow = time(ThroughputMode::Slow, staged);
        let fast = time(ThroughputMode::Fast, staged);
        assert!(
            (slow - fast).abs() < 1e-5,
            "staged={staged}: slow model {slow} s vs fast model {fast} s"
        );
    }
}

#[test]
fn restaging_overwrites_cleanly() {
    let (mut core, topo, spec) = setup(16);
    let leader = Comm::leader(&topo.spec);
    let mut p = Plan::new(0);
    staged_plan(&mut p, &core.pfs, &topo, &leader, &spec, vec![]).unwrap();
    core.submit(p);
    core.run_to_completion();
    // New data arrives (next layer); restage the same paths.
    for i in 0..32u64 {
        core.pfs.write(
            format!("/projects/run/f{i:03}.bin"),
            Blob::synthetic(4 * MB, 0xBEEF00 + i),
        );
    }
    let mut p = Plan::new(1);
    staged_plan(&mut p, &core.pfs, &topo, &leader, &spec, vec![]).unwrap();
    core.submit(p);
    core.run_to_completion();
    let orig = core.pfs.read("/projects/run/f007.bin").unwrap();
    let replica = core.nodes.read(9, "/tmp/run/f007.bin").unwrap();
    assert!(replica.same_content(orig), "restaged replica must be the new data");
}
