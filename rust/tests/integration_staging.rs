//! Integration: the staging hook and the naive baseline against the
//! full simulated machine — data-plane equivalence, timing shape, and
//! bit-reproducibility.

use xstage::cluster::{bgq, Topology};
use xstage::engine::SimCore;
use xstage::mpisim::Comm;
use xstage::pfs::{Blob, GpfsParams};
use xstage::simtime::flownet::ThroughputMode;
use xstage::simtime::plan::Plan;
use xstage::staging::{incremental_plan, naive_plan, read_phase, staged_plan, HookSpec};
use xstage::units::MB;

fn setup(nodes: u32) -> (SimCore, Topology, HookSpec) {
    setup_mode(nodes, ThroughputMode::Fast)
}

fn setup_mode(nodes: u32, mode: ThroughputMode) -> (SimCore, Topology, HookSpec) {
    let mut core = SimCore::with_mode(mode);
    let topo = Topology::build(bgq(nodes), GpfsParams::default(), &mut core.net);
    for i in 0..32u64 {
        core.pfs.write(
            format!("/projects/run/f{i:03}.bin"),
            Blob::synthetic(4 * MB, 0xC0FFEE + i),
        );
    }
    // Also a real-bytes file to checksum exactly.
    core.pfs.write(
        "/projects/run/params.txt",
        Blob::real((0..=255u8).cycle().take(100_000).collect()),
    );
    let spec = HookSpec::parse("broadcast to /tmp/run { /projects/run/* }").unwrap();
    (core, topo, spec)
}

#[test]
fn staged_and_naive_deliver_identical_data() {
    let run = |staged: bool| {
        let (mut core, topo, spec) = setup(32);
        let mut p = Plan::new(0);
        if staged {
            let comm = Comm::leader(&topo.spec);
            staged_plan(&mut p, &core.pfs, &topo, &comm, &spec, vec![]).unwrap();
        } else {
            let comm = Comm::world(&topo.spec);
            naive_plan(&mut p, &core.pfs, &topo, &comm, &spec, vec![]).unwrap();
        }
        core.submit(p);
        core.run_to_completion();
        core
    };
    let s = run(true);
    let n = run(false);
    // Every node holds identical content either way.
    for node in [0u32, 15, 31] {
        for i in 0..32 {
            let path = format!("/tmp/run/f{i:03}.bin");
            let a = s.nodes.read(node, &path).expect("staged replica");
            let b = n.nodes.read(node, &path).expect("naive replica");
            assert!(a.same_content(b), "{path} differs on node {node}");
        }
        let a = s.nodes.read(node, "/tmp/run/params.txt").unwrap();
        assert_eq!(
            a.to_bytes(),
            (0..=255u8).cycle().take(100_000).collect::<Vec<_>>()
        );
    }
}

#[test]
fn simulation_is_bit_reproducible() {
    let run = || {
        let (mut core, topo, spec) = setup(256);
        let leader = Comm::leader(&topo.spec);
        let world = Comm::world(&topo.spec);
        let mut p = Plan::new(0);
        let (m, done) =
            staged_plan(&mut p, &core.pfs, &topo, &leader, &spec, vec![]).unwrap();
        read_phase(&mut p, &topo, &world, m.total_bytes, vec![done]);
        core.submit(p);
        core.run_to_completion();
        (core.now, core.events_processed)
    };
    let a = run();
    let b = run();
    assert_eq!(a, b, "identical runs must produce identical clocks");
}

#[test]
fn staged_beats_naive_at_scale_but_not_small() {
    let time = |nodes: u32, staged: bool| {
        let (mut core, topo, spec) = setup(nodes);
        let mut p = Plan::new(0);
        if staged {
            let leader = Comm::leader(&topo.spec);
            let world = Comm::world(&topo.spec);
            let (m, done) =
                staged_plan(&mut p, &core.pfs, &topo, &leader, &spec, vec![]).unwrap();
            read_phase(&mut p, &topo, &world, m.total_bytes, vec![done]);
        } else {
            let comm = Comm::world(&topo.spec);
            naive_plan(&mut p, &core.pfs, &topo, &comm, &spec, vec![]).unwrap();
        }
        core.submit(p);
        core.run_to_completion();
        core.now.secs_f64()
    };
    // At 8K nodes the hook wins decisively.
    let s8k = time(8192, true);
    let n8k = time(8192, false);
    assert!(n8k > 1.5 * s8k, "at 8K: staged {s8k}, naive {n8k}");
    // At 64 nodes there is no contention to win against (naive may
    // even be faster since it skips the write+read detour).
    let s64 = time(64, true);
    let n64 = time(64, false);
    assert!(n64 < 2.0 * s64, "at 64: staged {s64}, naive {n64}");
}

#[test]
fn hook_metadata_cost_is_constant_in_ranks() {
    // The hook's glob runs once regardless of allocation size; naive
    // metadata grows with ranks.
    let meta_phase = |nodes: u32| {
        let (mut core, topo, spec) = setup(nodes);
        let leader = Comm::leader(&topo.spec);
        let mut p = Plan::new(0);
        staged_plan(&mut p, &core.pfs, &topo, &leader, &spec, vec![]).unwrap();
        core.submit(p);
        core.run_to_completion();
        core.metrics.phase_span("glob").unwrap().secs_f64()
    };
    let small = meta_phase(64);
    let large = meta_phase(4096);
    assert!((small - large).abs() < 1e-9, "glob cost must not scale: {small} vs {large}");
}

#[test]
fn throughput_models_agree_end_to_end() {
    // The component-incremental throughput model must reproduce the
    // reference (global-recompute) timings through the whole staging
    // stack: hook plan construction, MPI collectives, engine event
    // scheduling. Staged and naive pipelines, contended at 512 nodes.
    let time = |mode: ThroughputMode, staged: bool| {
        let (mut core, topo, spec) = setup_mode(512, mode);
        let mut p = Plan::new(0);
        if staged {
            let leader = Comm::leader(&topo.spec);
            let world = Comm::world(&topo.spec);
            let (m, done) =
                staged_plan(&mut p, &core.pfs, &topo, &leader, &spec, vec![]).unwrap();
            read_phase(&mut p, &topo, &world, m.total_bytes, vec![done]);
        } else {
            let comm = Comm::world(&topo.spec);
            naive_plan(&mut p, &core.pfs, &topo, &comm, &spec, vec![]).unwrap();
        }
        core.submit(p);
        core.run_to_completion();
        core.now.secs_f64()
    };
    for staged in [true, false] {
        let slow = time(ThroughputMode::Slow, staged);
        let fast = time(ThroughputMode::Fast, staged);
        assert!(
            (slow - fast).abs() < 1e-5,
            "staged={staged}: slow model {slow} s vs fast model {fast} s"
        );
    }
}

#[test]
fn evicted_files_restage_byte_identical() {
    // The evict -> incremental re-stage path must leave every node
    // replica byte-identical to the PFS original. Dataset A (~128 MB)
    // is staged, then dataset B (~128 MB) under a 200 MB/node budget
    // forcibly displaces part of A; the incremental re-stage moves
    // only the displaced files and restores exact bytes.
    let (mut core, topo, spec_a) = setup(16);
    core.nodes.set_capacity(Some(200 * MB));
    let leader = Comm::leader(&topo.spec);
    let mut p = Plan::new(0);
    let (ma, _) = staged_plan(&mut p, &core.pfs, &topo, &leader, &spec_a, vec![]).unwrap();
    core.submit(p);
    core.run_to_completion();
    assert_eq!(core.residency.evicted_bytes, 0, "A alone fits");

    for i in 0..32u64 {
        core.pfs.write(
            format!("/projects/other/g{i:03}.bin"),
            Blob::synthetic(4 * MB, 0xB00 + i),
        );
    }
    let spec_b = HookSpec::parse("broadcast to /tmp/other { /projects/other/*.bin }").unwrap();
    let mut p = Plan::new(1);
    let (mb, _) = staged_plan(&mut p, &core.pfs, &topo, &leader, &spec_b, vec![]).unwrap();
    core.submit(p);
    core.run_to_completion();
    assert!(core.residency.evicted_bytes > 0, "B must displace part of A");
    assert!(core.residency.mirrors(&core.nodes));
    let missing: Vec<_> = ma
        .transfers
        .iter()
        .filter(|t| !core.nodes.exists_on(0, &t.dst))
        .collect();
    assert!(!missing.is_empty(), "no A files were displaced");
    // B itself landed whole.
    for t in &mb.transfers {
        assert!(core.nodes.exists_on(5, &t.dst), "{} missing", t.dst);
    }

    // Incremental re-stage of A through the residency manager:
    // exactly the displaced delta moves, and the manager pins A's
    // surviving files so the re-stage cannot displace its own dataset.
    let mut catalog = xstage::catalog::Catalog::new();
    let id = catalog.register("run", "/projects/run", ma.transfers.len() as u64, ma.total_bytes);
    let mut res = xstage::staging::Residency::new();
    res.bind(id, spec_a.clone());
    let inc = res.stage_dataset(&mut core, &topo, &leader, id).unwrap();
    assert_eq!(inc.staged.len(), missing.len());
    assert_eq!(inc.total_files(), ma.transfers.len());
    assert!(inc.staged_bytes < ma.total_bytes);
    for t in &ma.transfers {
        let want = core.pfs.read(&t.src).unwrap();
        for node in [0u32, 7, 15] {
            let got = core
                .nodes
                .read(node, &t.dst)
                .unwrap_or_else(|| panic!("{} absent on node {node} after re-stage", t.dst));
            assert!(got.same_content(want), "{} differs on node {node}", t.dst);
        }
    }
    assert!(core.residency.mirrors(&core.nodes));
    // With A whole again, a further incremental plan moves nothing.
    let mut p = Plan::new(3);
    let (again, _) =
        incremental_plan(&mut p, &core.pfs, &core.nodes, &topo, &leader, &spec_a, false, vec![])
            .unwrap();
    assert!(again.staged.is_empty());
    assert_eq!(again.hit_rate(), 1.0);
}

#[test]
fn cache_aware_workflow_matches_baseline_after_staging() {
    // End-to-end differential: stage the dataset, run a task farm over
    // it. When the staged inputs are resident on every node the
    // locality-aware scheduler must reproduce the baseline exactly.
    use xstage::dataflow::graph::{Task, TaskGraph};
    use xstage::dataflow::sched::{run_workflow, SchedulerCfg};
    use xstage::units::Duration;
    let run = |locality: bool| {
        let (mut core, topo, spec) = setup(32);
        let leader = Comm::leader(&topo.spec);
        let world = Comm::world(&topo.spec);
        let mut p = Plan::new(0);
        let (m, _) = staged_plan(&mut p, &core.pfs, &topo, &leader, &spec, vec![]).unwrap();
        core.submit(p);
        core.run_to_completion();
        let mut g = TaskGraph::new();
        let files: Vec<String> = m.transfers.iter().map(|t| t.dst.clone()).collect();
        g.foreach(1024, |i| {
            Task::compute(format!("t{i}"), Duration::from_secs(3))
                .with_input(files[i % files.len()].clone(), None)
        });
        let cfg = SchedulerCfg { locality_aware: locality, ..Default::default() };
        run_workflow(&mut core, &topo, &world, g, cfg)
    };
    let base = run(false);
    let loc = run(true);
    assert_eq!(base.makespan, loc.makespan);
    assert_eq!(base.completion, loc.completion);
    assert_eq!(base.staged_read_bytes, loc.staged_read_bytes);
    assert_eq!(base.unstaged_read_bytes, 0);
    assert_eq!(loc.unstaged_read_bytes, 0);
}

#[test]
fn transfer_lists_are_deterministic_across_runs() {
    // Hook transfer lists (and therefore everything downstream of
    // them) must be reproducible: two identically-built simulations
    // resolve identical manifests, in sorted order.
    let manifest = || {
        let (core, topo, spec) = setup(8);
        let comm = Comm::leader(&topo.spec);
        let mut p = Plan::new(0);
        let (m, _) = staged_plan(&mut p, &core.pfs, &topo, &comm, &spec, vec![]).unwrap();
        m.transfers
            .iter()
            .map(|t| (t.src.clone(), t.dst.clone()))
            .collect::<Vec<_>>()
    };
    let a = manifest();
    let b = manifest();
    assert_eq!(a, b);
    let mut sorted = a.clone();
    sorted.sort();
    assert_eq!(a, sorted, "manifest must come out in sorted (glob) order");
}

#[test]
fn restaging_overwrites_cleanly() {
    let (mut core, topo, spec) = setup(16);
    let leader = Comm::leader(&topo.spec);
    let mut p = Plan::new(0);
    staged_plan(&mut p, &core.pfs, &topo, &leader, &spec, vec![]).unwrap();
    core.submit(p);
    core.run_to_completion();
    // New data arrives (next layer); restage the same paths.
    for i in 0..32u64 {
        core.pfs.write(
            format!("/projects/run/f{i:03}.bin"),
            Blob::synthetic(4 * MB, 0xBEEF00 + i),
        );
    }
    let mut p = Plan::new(1);
    staged_plan(&mut p, &core.pfs, &topo, &leader, &spec, vec![]).unwrap();
    core.submit(p);
    core.run_to_completion();
    let orig = core.pfs.read("/projects/run/f007.bin").unwrap();
    let replica = core.nodes.read(9, "/tmp/run/f007.bin").unwrap();
    assert!(replica.same_content(orig), "restaged replica must be the new data");
}
