//! Stub of the `xla-rs` API surface `xstage::runtime` compiles
//! against under the `pjrt-artifacts` feature.
//!
//! The stub exists so `--features pjrt-artifacts` builds in an offline
//! environment with no PJRT plugin: every entry point that would touch
//! a real backend returns [`Error`], starting with
//! [`PjRtClient::cpu`], which `Runtime::load` calls first — so callers
//! see one clear "PJRT backend unavailable" error instead of a link
//! failure. Deployments with the real `xla-rs` bindings point the
//! workspace `xla` dependency at that checkout instead; the types and
//! signatures here match the subset the runtime uses.

use std::fmt;

/// Error type standing in for `xla::Error`.
#[derive(Debug, Clone)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "xla-stub: {}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable<T>(what: &str) -> Result<T> {
    Err(Error(format!(
        "{what}: PJRT backend unavailable (built against the vendored xla-stub; \
         point the workspace `xla` dependency at a real xla-rs checkout)"
    )))
}

/// Host-side literal tensor (stub: carries no data).
#[derive(Debug, Clone)]
pub struct Literal;

impl Literal {
    pub fn vec1(_data: &[f32]) -> Literal {
        Literal
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        Ok(Literal)
    }

    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        unavailable("Literal::to_tuple")
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        unavailable("Literal::to_vec")
    }
}

/// Parsed HLO module (stub).
#[derive(Debug, Clone)]
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        unavailable("HloModuleProto::from_text_file")
    }
}

/// An XLA computation handle (stub).
#[derive(Debug, Clone)]
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// Device buffer returned by an execution (stub).
#[derive(Debug, Clone)]
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        unavailable("PjRtBuffer::to_literal_sync")
    }
}

/// Compiled executable (stub).
#[derive(Debug, Clone)]
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[Literal]) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable("PjRtLoadedExecutable::execute")
    }
}

/// PJRT client (stub: construction always fails).
#[derive(Debug)]
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        unavailable("PjRtClient::cpu")
    }

    pub fn platform_name(&self) -> String {
        "xla-stub".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        unavailable("PjRtClient::compile")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_construction_fails_gracefully() {
        let err = PjRtClient::cpu().unwrap_err();
        assert!(err.to_string().contains("PJRT backend unavailable"));
    }

    #[test]
    fn literal_roundtrip_is_inert() {
        let l = Literal::vec1(&[1.0, 2.0]);
        assert!(l.reshape(&[2]).is_ok());
        assert!(l.to_vec::<f32>().is_err());
    }
}
