//! Offline shim for the subset of the `anyhow` crate this workspace
//! uses: [`Error`], [`Result`], the [`anyhow!`]/[`bail!`]/[`ensure!`]
//! macros, and the [`Context`] extension trait.
//!
//! The build environment has no crate registry, so the workspace
//! vendors this API-compatible stand-in as a path dependency. It keeps
//! the ergonomics (`?` on any `std::error::Error`, context chaining,
//! format-style construction) while storing errors as a rendered
//! message chain. Swap in the real crate by editing the workspace
//! `Cargo.toml` if a registry becomes available — no source changes
//! needed.

use std::fmt::{self, Display};

/// `Result` defaulting the error type to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// A rendered error: message plus optional context chain.
///
/// Deliberately does **not** implement `std::error::Error`, mirroring
/// the real crate, so the blanket `From<E: std::error::Error>` impl
/// does not overlap the reflexive `From<Error> for Error`.
pub struct Error {
    msg: String,
}

impl Error {
    /// Construct from anything displayable.
    pub fn msg<M: Display>(message: M) -> Error {
        Error { msg: message.to_string() }
    }

    /// Prepend a context line, `context: original`.
    pub fn context<C: Display>(self, context: C) -> Error {
        Error { msg: format!("{context}: {}", self.msg) }
    }
}

impl Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        Error::msg(e)
    }
}

/// Attach context to an error, like `anyhow::Context`.
pub trait Context<T, E>: Sized {
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: Display + Send + Sync + 'static;

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E: Into<Error>> Context<T, E> for Result<T, E> {
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: Display + Send + Sync + 'static,
    {
        self.map_err(|e| e.into().context(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| e.into().context(f()))
    }
}

/// Construct an [`Error`] from a format string or displayable value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(::std::format!($msg))
    };
    ($fmt:expr, $($arg:tt)+) => {
        $crate::Error::msg(::std::format!($fmt, $($arg)+))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
}

/// Return early with an [`Error`].
#[macro_export]
macro_rules! bail {
    ($($t:tt)+) => {
        return ::core::result::Result::Err($crate::anyhow!($($t)+))
    };
}

/// Return early with an [`Error`] unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return ::core::result::Result::Err($crate::Error::msg(::std::concat!(
                "condition failed: ",
                ::std::stringify!($cond)
            )));
        }
    };
    ($cond:expr, $($rest:tt)+) => {
        if !($cond) {
            return ::core::result::Result::Err($crate::anyhow!($($rest)+));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parses(s: &str) -> Result<u64> {
        let n: u64 = s.parse()?; // ParseIntError -> Error via blanket From
        ensure!(n < 100, "too big: {n}");
        Ok(n)
    }

    #[test]
    fn question_mark_and_ensure() {
        assert_eq!(parses("42").unwrap(), 42);
        assert!(parses("xyz").is_err());
        assert!(parses("200").unwrap_err().to_string().contains("too big: 200"));
    }

    #[test]
    fn macro_forms() {
        let plain = anyhow!("plain");
        assert_eq!(plain.to_string(), "plain");
        let x = 7;
        let inline = anyhow!("x = {x}");
        assert_eq!(inline.to_string(), "x = 7");
        let positional = anyhow!("{} {}", "a", 1);
        assert_eq!(positional.to_string(), "a 1");
        let from_value = anyhow!(String::from("owned"));
        assert_eq!(from_value.to_string(), "owned");
    }

    fn bails() -> Result<()> {
        bail!("nope: {}", 3);
    }

    #[test]
    fn bail_returns_err() {
        assert_eq!(bails().unwrap_err().to_string(), "nope: 3");
    }

    #[test]
    fn context_chains() {
        let base: Result<(), std::io::Error> = Err(std::io::Error::new(
            std::io::ErrorKind::NotFound,
            "gone",
        ));
        let e = base.context("reading manifest").unwrap_err();
        assert!(e.to_string().starts_with("reading manifest: "));
        let again: Result<()> = Err(e);
        let e2 = again.with_context(|| format!("loading {}", "dir")).unwrap_err();
        assert!(e2.to_string().starts_with("loading dir: reading manifest: "));
    }

    #[test]
    fn debug_matches_display() {
        let e = anyhow!("boom");
        assert_eq!(format!("{e:?}"), format!("{e}"));
    }
}
