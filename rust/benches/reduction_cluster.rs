//! Bench: regenerate the SVI-A table (NF reduction, 736 images on
//! Orthros — paper: 106 s at 320 cores) plus host-time measurements of
//! the *real* per-frame reduction kernel (native Rust and, when
//! artifacts exist, the AOT Pallas path on PJRT).
//!
//! Run: `cargo bench --bench reduction_cluster`

use xstage::experiments::reduction;
use xstage::hedm::detector::splat;
use xstage::hedm::reduce::{reduce_frame_artifact, reduce_frame_native, ReduceParams};
use xstage::runtime::Runtime;
use xstage::util::bench::{bench, bench_n, section};
use xstage::util::prng::Pcg64;

fn main() {
    section("SVI-A — virtual results (paper: 106 s at 320 cores)");
    let result = reduction::run();
    result.print();
    let at320 = result
        .series_named("makespan s")
        .unwrap()
        .iter()
        .find(|(c, _)| *c == 320.0)
        .unwrap()
        .1;
    assert!((at320 - 106.0).abs() < 12.0, "320-core makespan {at320}");
    println!("\n320-core point OK: {at320:.1} s vs paper 106 s");

    section("real per-frame reduction kernel (host time)");
    let n = 512usize;
    let mut rng = Pcg64::new(1);
    let mut frame = vec![0f32; n * n];
    for px in frame.iter_mut() {
        *px = 40.0 + rng.normal() as f32 * 3.0;
    }
    for i in 0..16 {
        splat(&mut frame, n, 30.0 + 28.0 * i as f64, 256.0, 400.0, 1.5);
    }
    let dark = vec![40.0f32; n * n];
    let params = ReduceParams::default();
    bench("reduce/native-512", || {
        let r = reduce_frame_native(&frame, &dark, n, &params);
        std::hint::black_box(r.count);
    });
    if Runtime::artifacts_available() {
        let mut rt = Runtime::load(Runtime::default_dir()).unwrap();
        // Warm the executable cache before timing.
        let _ = reduce_frame_artifact(&mut rt, &frame, &dark).unwrap();
        bench_n("reduce/artifact-512 (PJRT)", 10, || {
            let r = reduce_frame_artifact(&mut rt, &frame, &dark).unwrap();
            std::hint::black_box(r.count);
        });
    } else {
        println!("(artifacts missing — run `make artifacts` for the PJRT bench)");
    }
}
