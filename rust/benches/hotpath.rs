//! Bench: hot-path microbenchmarks for the performance pass
//! (EXPERIMENTS.md SPerf). Targets, per DESIGN.md SPerf:
//!
//! - DES core >= 1M events/s
//! - flow-network recompute O(bundles), independent of node count
//! - scheduler >= 100K task dispatches/s
//! - glob / CCL / reduction kernels at memory-bound rates
//! - PJRT fit_orientation call throughput (candidates/s)
//!
//! Run: `cargo bench --bench hotpath`

use std::collections::VecDeque;

use xstage::cluster::{bgq, orthros, Topology};
use xstage::dataflow::graph::{Task, TaskGraph};
use xstage::dataflow::sched::{run_workflow, SchedulerCfg};
use xstage::engine::SimCore;
use xstage::hedm::ccl::find_peaks;
use xstage::hedm::detector::splat;
use xstage::hedm::fit::{ArtifactScorer, Scorer};
use xstage::hedm::geometry::simulate_spots;
use xstage::mpisim::Comm;
use xstage::pfs::{Blob, GpfsParams, ParallelFs};
use xstage::simtime::flownet::{Capacity, FlowId, FlowNet, LinkId, ThroughputMode};
use xstage::simtime::plan::Plan;
use xstage::units::{Duration, GB, MB};
use xstage::util::bench::{bench, bench_n, section, smoke};
use xstage::util::prng::Pcg64;

fn bench_engine_events() {
    section("L3: discrete-event engine");
    // 100K delay steps in one plan: pure heap + dispatch cost.
    let s = bench_n("engine/100k-delay-steps", 3, || {
        let mut core = SimCore::new();
        let mut p = Plan::new(0);
        for i in 0..100_000u64 {
            p.delay(Duration(1 + i % 977), vec![], "d");
        }
        core.submit(p);
        core.run_to_completion();
        std::hint::black_box(core.events_processed);
    });
    println!("  -> {:.2}M events/s", 0.1 / s.median);
}

fn bench_flownet() {
    section("L3: flow-network recompute (must be O(bundles), not O(nodes))");
    for bundles in [10usize, 100, 1000] {
        let mut net = FlowNet::new();
        let links: Vec<_> = (0..8)
            .map(|i| net.add_link(format!("l{i}"), Capacity::Fixed(10.0 * GB as f64)))
            .collect();
        let mut rng = Pcg64::new(1);
        for i in 0..bundles {
            let path = vec![links[i % 8], links[(i + 3) % 8]];
            net.start(path, 1 + rng.below(8192), GB);
        }
        // force_recompute: a plain recompute() is dirty-gated and would
        // no-op after the first iteration.
        bench_n(&format!("flownet/recompute-{bundles}-bundles"), 20, || {
            net.force_recompute();
        });
    }
}

/// The high-churn scenario the incremental model exists for: many
/// link-disjoint components (independent beamline pipelines, detector
/// streams, task farms), with starts/completions landing in one
/// component at a time. The slow model re-waterfills *everything* per
/// change; the fast model touches only the dirty component, so the
/// per-op cost is independent of how many other components exist.
fn bench_flownet_churn() {
    section("L3: flow-network churn — component-scoped vs global recompute");
    let ncomps = 64usize;
    let flows_per = 4usize;
    let ops_per_iter = 100usize;

    let run = |mode: ThroughputMode| {
        let mut net = FlowNet::with_mode(mode);
        let links: Vec<LinkId> = (0..ncomps)
            .map(|i| net.add_link(format!("grp{i}"), Capacity::Fixed(10.0 * GB as f64)))
            .collect();
        let mut queue: VecDeque<(usize, FlowId)> = VecDeque::new();
        for (c, &l) in links.iter().enumerate() {
            for m in 0..flows_per {
                queue.push_back((c, net.start(vec![l], 1 + m as u64, GB)));
            }
        }
        net.recompute();
        let name = format!(
            "flownet/churn-{ncomps}x{flows_per}-{}",
            match mode {
                ThroughputMode::Slow => "slow",
                ThroughputMode::Fast => "fast",
            }
        );
        bench_n(&name, 10, || {
            // Steady-state churn: complete the oldest flow, start a
            // replacement in the same component, settle.
            for _ in 0..ops_per_iter {
                let (c, id) = queue.pop_front().unwrap();
                net.complete(id);
                let fresh = net.start(vec![links[c]], 1, GB);
                net.recompute();
                queue.push_back((c, fresh));
            }
        })
    };

    let slow = run(ThroughputMode::Slow);
    let fast = run(ThroughputMode::Fast);
    let speedup = slow.median / fast.median;
    println!(
        "  -> {ncomps} components x {flows_per} flows: fast is {speedup:.1}x \
         the slow (global) model per churn op"
    );
    if !smoke() {
        assert!(
            speedup >= 5.0,
            "component-scoped recompute must beat the global pass >=5x \
             on {ncomps} independent components (got {speedup:.1}x)"
        );
    }
}

/// The storage residency queries the serve/campaign dispatch inner
/// loops hammer: `coverage_of` per task input (locality placement) and
/// `paths_on` per node (gather's local glob). Coverage is memoized
/// beside each path's replica list, so queries must be borrows —
/// never a rescan of every replica.
fn bench_storage_queries() {
    use xstage::cluster::NodeStores;
    section("L3: storage residency queries (memoized coverage)");
    let paths = 256usize;
    let mut ns = NodeStores::new();
    for p in 0..paths {
        // Split every path into several replicas (the worst case the
        // old scan-per-query code degraded on).
        for seg in 0..4u32 {
            ns.write_range(seg * 16, seg * 16 + 7, format!("/tmp/ds/f{p:04}.bin"),
                           Blob::synthetic(MB, p as u64));
        }
    }
    // Micro-assert: repeated coverage queries return the *same* memoized
    // slice (a borrow, not a fresh allocation or replica walk).
    let probe = "/tmp/ds/f0007.bin";
    assert_eq!(ns.coverage_of(probe).len(), 4);
    assert_eq!(
        ns.coverage_of(probe).as_ptr(),
        ns.coverage_of(probe).as_ptr(),
        "coverage_of must return the memoized slice, not a rebuild"
    );
    // Keys prebuilt outside the timed loop: the bench measures the
    // memoized lookup, not String formatting.
    let keys: Vec<String> = (0..paths).map(|p| format!("/tmp/ds/f{p:04}.bin")).collect();
    let s = bench_n("storage/coverage_of-256paths", 10, || {
        let mut hits = 0usize;
        for k in &keys {
            let c = ns.coverage_of(k);
            hits += c.iter().filter(|&&(a, b)| (a..=b).contains(&33)).count();
        }
        std::hint::black_box(hits);
    });
    println!("  -> {:.1}M coverage queries/s", paths as f64 / s.median / 1e6);
    bench_n("storage/paths_on-node33", 10, || {
        std::hint::black_box(ns.paths_on(33).len());
    });
}

fn bench_scheduler() {
    section("L3: ADLB scheduler dispatch");
    let s = bench_n("sched/100k-tasks-8192-ranks", 3, || {
        let mut core = SimCore::new();
        let topo = Topology::build(bgq(512), GpfsParams::default(), &mut core.net);
        let comm = Comm::world(&topo.spec);
        let mut g = TaskGraph::new();
        g.foreach(100_000, |i| {
            Task::compute(format!("t{i}"), Duration::from_secs(30))
        });
        let stats = run_workflow(&mut core, &topo, &comm, g, SchedulerCfg::default());
        std::hint::black_box(stats.makespan);
    });
    println!("  -> {:.0}K tasks/s dispatched+completed", 100.0 / s.median);
}

fn bench_staging_sim() {
    section("L3: full staging-experiment simulation");
    bench_n("staging/fig11-staged-8192", 5, || {
        let _ = xstage::experiments::fig11::run_staged(8192);
    });
}

fn bench_glob() {
    section("L3: filesystem glob");
    let mut fs = ParallelFs::new();
    for d in 0..100 {
        for f in 0..100 {
            fs.write(format!("/data/run{d:02}/f{f:03}.bin"), Blob::synthetic(MB, 1));
        }
    }
    bench("glob/10k-files", || {
        std::hint::black_box(fs.glob("/data/run4?/f*.bin").len());
    });
}

fn bench_ccl() {
    section("science: connected components (512^2, 32 spots)");
    let n = 512;
    let mut img = vec![0f32; n * n];
    let mut rng = Pcg64::new(2);
    for _ in 0..32 {
        splat(
            &mut img,
            n,
            rng.range_f64(10.0, 500.0),
            rng.range_f64(10.0, 500.0),
            400.0,
            1.5,
        );
    }
    let mask: Vec<f32> = img.iter().map(|&v| if v > 50.0 { 1.0 } else { 0.0 }).collect();
    bench("ccl/find_peaks-512", || {
        std::hint::black_box(find_peaks(&mask, &img, n, 2).len());
    });
}

fn bench_forward_model() {
    section("science: forward model (58 G-vectors)");
    let g = xstage::hedm::geometry::Geom::default();
    let mut rng = Pcg64::new(3);
    bench("geometry/simulate_spots", || {
        let e = [
            rng.range_f64(0.0, 6.28),
            rng.range_f64(0.0, 3.14),
            rng.range_f64(0.0, 6.28),
        ];
        std::hint::black_box(simulate_spots(e, &g).len());
    });
}

fn bench_pjrt_fit() {
    use xstage::runtime::Runtime;
    if !Runtime::artifacts_available() {
        println!("(artifacts missing — skipping PJRT fit bench)");
        return;
    }
    section("L1/L2: AOT fit_orientation on PJRT (batch=256 candidates)");
    let mut rt = Runtime::load(Runtime::default_dir()).unwrap();
    let geom = xstage::hedm::geometry::Geom::from_manifest(&rt.manifest.config);
    let obs = simulate_spots([0.9, 1.3, 0.2], &geom);
    let mut scorer = ArtifactScorer::new(&mut rt, &obs);
    let mut rng = Pcg64::new(4);
    let eulers: Vec<[f64; 3]> = (0..256)
        .map(|_| {
            [
                rng.range_f64(0.0, 6.28),
                rng.range_f64(0.0, 3.14),
                rng.range_f64(0.0, 6.28),
            ]
        })
        .collect();
    let _ = scorer.score(&eulers).unwrap(); // warm compile
    let s = bench_n("fit/score-256-candidates", 10, || {
        std::hint::black_box(scorer.score(&eulers).unwrap().len());
    });
    println!("  -> {:.0}K candidates/s", 0.256 / s.median);
}

fn bench_cluster_farm() {
    section("L3: Orthros task farm (Fig 12 class)");
    bench_n("farm/720-tasks-320-cores", 5, || {
        let mut core = SimCore::new();
        let topo = Topology::build(orthros(), GpfsParams::default(), &mut core.net);
        let comm = Comm::world(&topo.spec);
        let g = xstage::hedm::workloads::ff1_graph(42);
        // Inputs present node-locally.
        let (lo, hi) = comm.node_range();
        for i in 0..720 {
            core.nodes.write_range(lo, hi, format!("/tmp/ff/frame_{i:04}.bin"),
                                   Blob::synthetic(8 * MB, i as u64));
        }
        let stats = run_workflow(&mut core, &topo, &comm, g, SchedulerCfg::default());
        std::hint::black_box(stats.makespan);
    });
}

fn main() {
    bench_engine_events();
    bench_flownet();
    bench_flownet_churn();
    bench_storage_queries();
    bench_scheduler();
    bench_staging_sim();
    bench_glob();
    bench_ccl();
    bench_forward_model();
    bench_cluster_farm();
    bench_pjrt_fit();
}
