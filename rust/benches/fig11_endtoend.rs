//! Bench: regenerate Fig 11 (end-to-end input bandwidth, hook vs
//! naive) + the SVI-B wall-time table, and check the paper's shape:
//! who wins (hook), by what factor (~5x at 8K nodes), where the
//! advantage appears (grows with scale), and the flat Read phase.
//!
//! Run: `cargo bench --bench fig11_endtoend`

use xstage::experiments::fig11;
use xstage::util::bench::{bench_n, section};

fn main() {
    section("Fig 11 — virtual results (paper: 101 vs 21 GB/s at 8,192 nodes)");
    let result = fig11::default();
    result.print();

    let staged = result.series_named("staged GB/s").unwrap();
    let naive = result.series_named("naive GB/s").unwrap();
    // Shape: the hook wins everywhere measured at >= 512 nodes, and
    // its advantage grows with scale.
    let ratio_first = staged[0].1 / naive[0].1;
    let ratio_last = staged.last().unwrap().1 / naive.last().unwrap().1;
    assert!(ratio_last > ratio_first, "advantage must grow with scale");
    assert!(
        ratio_last > 4.0 && ratio_last < 6.5,
        "8K-node factor {ratio_last} (paper ~4.8x)"
    );
    println!("\nfactor at scale: {ratio_last:.1}x (paper: ~4.8x) — OK");

    section("SVI-B phase wall times at 8,192 nodes");
    let p = fig11::run_staged(8192);
    println!(
        "staging+write {:.1} s | read {:.1} s | total {:.2} s (paper: 35.9 + 10.8 = 46.75 s)",
        p.stage_write_secs, p.read_secs, p.total_secs
    );
    assert!((p.total_secs - 46.75).abs() < 2.5);
    assert!((p.read_secs - 10.8).abs() < 0.2, "Read must be flat at 10.8 s");

    section("host cost per experiment point");
    bench_n("fig11/staged@8192", 5, || {
        let _ = fig11::run_staged(8192);
    });
    bench_n("fig11/naive@8192", 5, || {
        let _ = fig11::run_naive(8192);
    });
}
