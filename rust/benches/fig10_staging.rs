//! Bench: regenerate Fig 10 (Staging+Write aggregate bandwidth vs
//! node count) and measure the simulator's host-time cost per point.
//!
//! Run: `cargo bench --bench fig10_staging`

use xstage::experiments::fig10;
use xstage::util::bench::{bench_n, section};

fn main() {
    section("Fig 10 — virtual results (paper: 134 GB/s at 8,192 nodes)");
    let result = fig10::default();
    result.print();

    // Shape assertions: near-linear scaling to the ION-layer ceiling.
    let pts = result.series_named("staging+write GB/s").unwrap();
    let (n0, bw0) = pts[0];
    let (n1, bw1) = *pts.last().unwrap();
    assert!(
        bw1 / bw0 > 0.8 * n1 / n0,
        "staging bandwidth must scale near-linearly: {pts:?}"
    );
    let endpoint = pts.iter().find(|(n, _)| *n == 8192.0).map(|(_, b)| *b);
    if let Some(bw) = endpoint {
        assert!((bw - 134.0).abs() < 8.0, "8192-node endpoint {bw} GB/s");
        println!("\nendpoint OK: {bw:.1} GB/s vs paper 134 GB/s");
    }

    section("host cost of one Fig 10 sweep point");
    for nodes in [512u32, 8192] {
        bench_n(&format!("fig10/nodes={nodes}"), 5, || {
            let _ = fig10::run_point(nodes);
        });
    }
}
