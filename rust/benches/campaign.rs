//! Bench: the multi-campaign residency session under node-memory
//! pressure — the capacity era of the paper's "extended period"
//! staging claim.
//!
//! Prints the virtual-session comparison (full restage vs residency),
//! asserts the residency acceptance bar (>= 2x fewer staged bytes,
//! zero checksum mismatches), and measures host time for both
//! policies. With `XSTAGE_BENCH_JSON` set the measurements emit one
//! JSON point each — CI uploads them per run, and the cross-PR
//! `BENCH_residency.json` trajectory accumulates those points.
//!
//! Run: `cargo bench --bench campaign`

use xstage::experiments::campaign;
use xstage::simtime::flownet::ThroughputMode;
use xstage::units::fmt_bytes;
use xstage::util::bench::{bench_n, section};

fn main() {
    section("residency — multi-campaign interactive session");
    let result = campaign::run();
    result.print();

    let full = campaign::run_session(64, false, ThroughputMode::Fast);
    let resi = campaign::run_session(64, true, ThroughputMode::Fast);
    assert_eq!(full.checksum_mismatches, 0, "full-restage data plane corrupt");
    assert_eq!(resi.checksum_mismatches, 0, "residency data plane corrupt");
    assert!(
        full.staged_bytes >= 2 * resi.staged_bytes,
        "residency must stage >=2x fewer bytes: {} vs {}",
        fmt_bytes(full.staged_bytes),
        fmt_bytes(resi.staged_bytes),
    );
    println!(
        "\nstaged {} (full) vs {} (residency): {:.2}x fewer; hit rate {:.0}%, evicted {}",
        fmt_bytes(full.staged_bytes),
        fmt_bytes(resi.staged_bytes),
        full.staged_bytes as f64 / resi.staged_bytes as f64,
        100.0 * resi.hit_rate,
        fmt_bytes(resi.evicted_bytes),
    );

    section("host-time: session simulation throughput");
    bench_n("campaign/residency-session-64", 3, || {
        let out = campaign::run_session(64, true, ThroughputMode::Fast);
        assert_eq!(out.checksum_mismatches, 0);
    });
    bench_n("campaign/full-restage-session-64", 3, || {
        let out = campaign::run_session(64, false, ThroughputMode::Fast);
        assert_eq!(out.checksum_mismatches, 0);
    });
    bench_n("campaign/residency-session-64-slow-model", 3, || {
        let out = campaign::run_session(64, true, ThroughputMode::Slow);
        assert_eq!(out.checksum_mismatches, 0);
    });
}
