//! Bench: the SVI-B worker-cache experiment ("reduces input time to
//! effectively zero for subsequent tasks") + the glob-storm ablation
//! (rank-0 glob + bcast vs glob-on-every-rank — the SIV design note).
//!
//! Run: `cargo bench --bench cache_reuse`

use xstage::cluster::{bgq, Topology};
use xstage::engine::SimCore;
use xstage::experiments::cache;
use xstage::mpisim::Comm;
use xstage::pfs::{Blob, GpfsParams};
use xstage::simtime::plan::Plan;
use xstage::staging::naive::{naive_plan, naive_plan_with_glob_storm};
use xstage::staging::HookSpec;
use xstage::units::MB;
use xstage::util::bench::section;

fn main() {
    section("SVI-B — worker input cache");
    let result = cache::run();
    result.print();
    let pts = result.series_named("makespan s").unwrap();
    let (cold, warm) = (pts[0].1, pts[1].1);
    assert!(warm < cold, "cache must reduce makespan: cold {cold}, warm {warm}");
    println!("\ncache saves {:.1} s ({:.0}%)", cold - warm, 100.0 * (1.0 - warm / cold));

    section("ablation: glob-on-every-rank metadata storm (SIV)");
    let run = |storm: bool| {
        let mut core = SimCore::new();
        let topo = Topology::build(bgq(512), GpfsParams::default(), &mut core.net);
        for i in 0..64 {
            core.pfs
                .write(format!("/data/f{i:03}.bin"), Blob::synthetic(MB, i));
        }
        let spec = HookSpec::parse("broadcast to /tmp/d { /data/*.bin }").unwrap();
        let comm = Comm::world(&topo.spec);
        let mut p = Plan::new(0);
        if storm {
            naive_plan_with_glob_storm(&mut p, &core.pfs, &topo, &comm, &spec, vec![])
                .unwrap();
        } else {
            naive_plan(&mut p, &core.pfs, &topo, &comm, &spec, vec![]).unwrap();
        }
        core.submit(p);
        core.run_to_completion();
        core.now.secs_f64()
    };
    let plain = run(false);
    let storm = run(true);
    println!("512 nodes x 16 ranks, 64 files:");
    println!("  single glob + bcast : {plain:.1} s");
    println!("  glob on every rank  : {storm:.1} s  (+{:.1} s metadata serialization)", storm - plain);
    assert!(storm > plain + 5.0, "the storm must visibly hurt");
}
