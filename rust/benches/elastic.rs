//! Bench: elastic multi-tenant serving — weighted admission, node
//! churn, keep-alive/prewarm policies.
//!
//! Prints the bursty x diurnal x churn matrix, then asserts the
//! acceptance bar:
//!
//! - **seed identity** — equal weights with policies off replays the
//!   plain single-tenant service bit-for-bit (rule E1: the weighted
//!   pick degenerates to the literal seed FIFO);
//! - **fairness wins** — weighted admission beats FIFO on the starved
//!   tenant's P99 at every bursty matrix point;
//! - **policy wins** — keep-alive (fixed and adaptive) cuts the hot
//!   tenant's GPFS re-read bytes vs the no-policy arm at every
//!   diurnal matrix point;
//! - **starvation-freedom** — every queued session is admitted within
//!   the run (finite admission wait, every session served), on every
//!   matrix point including under pool churn.
//!
//! With `XSTAGE_BENCH_JSON` set the measurements emit one JSON point
//! each — CI uploads them per run as the `BENCH_elastic.json` artifact.
//!
//! Run: `cargo bench --bench elastic`

use xstage::experiments::elastic;
use xstage::simtime::flownet::ThroughputMode;
use xstage::staging::{run_serve, PolicyKind, ServeOutcome, ServiceCfg, TenantsCfg};
use xstage::util::bench::{bench_n, section, smoke};

fn assert_starvation_free(out: &ServeOutcome, what: &str) {
    assert_eq!(out.turnaround_secs.len(), out.sessions, "{what}: a session was never served");
    assert!(
        out.admit_wait_secs.iter().all(|w| w.is_finite() && *w <= out.virtual_secs),
        "{what}: a queued session waited unbounded"
    );
}

fn main() {
    section("elastic — weighted tenants, keep-alive/prewarm, pool churn");
    let sessions = if smoke() { 6 } else { elastic::SESSIONS };
    elastic::run_with(sessions, elastic::SEED).print();

    // Acceptance: equal weights + policies off is the seed service,
    // bit for bit — the multi-tenant layer must cost nothing when it
    // expresses no preference.
    let plain = run_serve(2, &ServiceCfg { sessions, ..Default::default() }, ThroughputMode::Fast);
    let tenanted = run_serve(
        2,
        &ServiceCfg {
            sessions,
            tenants: TenantsCfg { weights: vec![3, 3] },
            policy: PolicyKind::None,
            ..Default::default()
        },
        ThroughputMode::Fast,
    );
    assert_eq!(plain.turnaround_secs, tenanted.turnaround_secs);
    assert_eq!(plain.virtual_secs, tenanted.virtual_secs);
    assert_eq!(plain.staged_bytes, tenanted.staged_bytes);
    assert_eq!(plain.peak_queue, tenanted.peak_queue);
    assert_eq!(plain.admission_order, tenanted.admission_order);
    println!("equal-weight/policy-off replay reproduces the plain service bit-for-bit");

    // Acceptance: weighted admission beats FIFO on the starved
    // tenant's P99 at every bursty point, and nobody starves.
    for &burst in elastic::BURSTS {
        let fifo = elastic::bursty_point(burst, false, elastic::SEED);
        let weighted = elastic::bursty_point(burst, true, elastic::SEED);
        assert_starvation_free(&fifo, "bursty fifo");
        assert_starvation_free(&weighted, "bursty weighted");
        let (fp, wp) = (elastic::tenant_p99(&fifo, 1), elastic::tenant_p99(&weighted, 1));
        assert!(
            wp < fp,
            "weighted lost the victim P99 at burst {burst}: {wp:.2}s vs {fp:.2}s"
        );
        assert_eq!(fifo.staged_bytes, weighted.staged_bytes, "burst {burst} moved extra bytes");
    }
    println!(
        "all {} bursty points: weighted victim P99 < FIFO victim P99, starvation-free",
        elastic::BURSTS.len()
    );

    // Acceptance: keep-alive/prewarm cut the hot tenant's GPFS
    // re-read bytes vs no-policy at every diurnal point.
    for &sweepers in elastic::SWEEPERS {
        let none = elastic::diurnal_point(sweepers, PolicyKind::None, elastic::SEED);
        assert_starvation_free(&none, "diurnal none");
        for (arm, policy) in elastic::policy_arms().into_iter().skip(1) {
            let out = elastic::diurnal_point(sweepers, policy, elastic::SEED);
            assert_starvation_free(&out, "diurnal policy");
            assert!(
                out.tenant_gpfs_bytes[0] < none.tenant_gpfs_bytes[0],
                "{arm} did not cut hot-tenant GPFS bytes at {sweepers} sweepers: {} vs {}",
                out.tenant_gpfs_bytes[0],
                none.tenant_gpfs_bytes[0]
            );
            assert!(out.warm_hits >= 1, "{arm} never served a warm hit");
        }
    }
    println!(
        "all {} diurnal points: keep-alive/prewarm GPFS bytes < no-policy, warm hits served",
        elastic::SWEEPERS.len()
    );

    // Acceptance: pool churn still serves every session, and the
    // zero-event control is the static pool.
    for &events in elastic::CHURN_EVENTS {
        let out = elastic::churn_point(events, sessions, elastic::SEED);
        assert_starvation_free(&out, "churn");
        if events == 0 {
            assert_eq!(out.pool_events, 0);
        } else {
            assert!(out.pool_events > 0, "churn point {events} never fired a pool event");
            assert!(out.min_warm_nodes >= 2, "pool shrank below its floor");
        }
        let again = elastic::churn_point(events, sessions, elastic::SEED);
        assert_eq!(out.turnaround_secs, again.turnaround_secs, "churn {events} diverged");
    }
    println!(
        "all {} churn points: starvation-free under pool churn, deterministic",
        elastic::CHURN_EVENTS.len()
    );

    section("host-time: elastic serve simulation throughput");
    let burst = *elastic::BURSTS.last().unwrap();
    let sweepers = *elastic::SWEEPERS.last().unwrap();
    bench_n("elastic/bursty-weighted-point", 3, || {
        let out = elastic::bursty_point(burst, true, elastic::SEED);
        assert_eq!(out.turnaround_secs.len(), out.sessions);
    });
    bench_n("elastic/diurnal-adaptive-point", 3, || {
        let out = elastic::diurnal_point(sweepers, elastic::policy_arms()[2].1, elastic::SEED);
        assert_eq!(out.turnaround_secs.len(), out.sessions);
    });
    let events = *elastic::CHURN_EVENTS.last().unwrap();
    bench_n("elastic/churn-point", 3, || {
        let out = elastic::churn_point(events, sessions, elastic::SEED);
        assert_eq!(out.turnaround_secs.len(), out.sessions);
    });
}
