//! Bench: the interactive serving matrix — staged-resident serving vs
//! naive GPFS re-reads.
//!
//! Prints the scenario-matrix comparison table, asserts the
//! acceptance bar (staged P99 turnaround strictly beats the naive
//! baseline at **every** matrix point, deterministically reproduced
//! across two same-seed runs), and measures host time for a serve
//! run under both throughput models. With `XSTAGE_BENCH_JSON` set the
//! measurements emit one JSON point each — CI uploads them per run as
//! the `BENCH_serve.json` artifact.
//!
//! Run: `cargo bench --bench serve`

use xstage::experiments::serve;
use xstage::simtime::flownet::ThroughputMode;
use xstage::staging::service::{run_serve, ServeMode};
use xstage::util::bench::{bench_n, section, smoke};

fn main() {
    section("serve — interactive sessions over staged data");
    let sessions = if smoke() { 8 } else { serve::SESSIONS };
    let result = serve::run_with(sessions, 42);
    result.print();

    // Acceptance: staged beats naive on P99 at every matrix point,
    // and the turnaround tables are bit-identical across same-seed
    // runs.
    for pt in serve::matrix() {
        let (s1, n1) = serve::run_point(&pt, sessions, 42);
        let (s2, _) = serve::run_point(&pt, sessions, 42);
        let (sp, np) = (s1.percentiles.unwrap(), n1.percentiles.unwrap());
        assert!(
            sp.p99 < np.p99,
            "staged P99 {} must beat naive P99 {} at {pt:?}",
            sp.p99,
            np.p99
        );
        assert_eq!(
            s1.turnaround_secs, s2.turnaround_secs,
            "same-seed serve runs diverged at {pt:?}"
        );
        assert_eq!(s1.reads.unstaged_bytes, 0, "staged serving re-read the shared FS");
    }
    println!(
        "\nall {} matrix points: staged P99 < naive P99, deterministic",
        serve::matrix().len()
    );

    section("host-time: serve simulation throughput");
    let pt = serve::matrix()[0];
    bench_n("serve/staged-session-matrix-point", 3, || {
        let out = run_serve(
            pt.nodes,
            &pt.cfg(ServeMode::Staged, sessions, 42),
            ThroughputMode::Fast,
        );
        assert_eq!(out.sessions, sessions);
    });
    bench_n("serve/naive-session-matrix-point", 3, || {
        let out = run_serve(
            pt.nodes,
            &pt.cfg(ServeMode::Naive, sessions, 42),
            ThroughputMode::Fast,
        );
        assert_eq!(out.sessions, sessions);
    });
    bench_n("serve/staged-session-slow-model", 3, || {
        let out = run_serve(
            pt.nodes,
            &pt.cfg(ServeMode::Staged, sessions, 42),
            ThroughputMode::Slow,
        );
        assert_eq!(out.sessions, sessions);
    });
}
