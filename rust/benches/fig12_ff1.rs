//! Bench: regenerate Fig 12 (FF-HEDM stage 1 makespan scaling — 720
//! peak-search jobs, 5-160 s each, on Orthros).
//!
//! Run: `cargo bench --bench fig12_ff1`

use xstage::experiments::fig12;
use xstage::util::bench::{bench_n, section};

fn main() {
    section("Fig 12 — virtual results (720 jobs on Orthros)");
    let result = fig12::default();
    result.print();

    let pts = result.series_named("makespan s").unwrap();
    // Shape: monotone decreasing makespan, flattening at high core
    // counts (straggler bound), never below the longest task (160 s).
    for w in pts.windows(2) {
        assert!(w[1].1 <= w[0].1 + 1e-9, "makespan must not increase: {pts:?}");
    }
    let last = pts.last().unwrap().1;
    assert!(last >= 150.0, "cannot beat the longest task: {last}");
    let speedup_early = pts[0].1 / pts[1].1;
    let speedup_late = pts[pts.len() - 2].1 / pts[pts.len() - 1].1;
    assert!(
        speedup_early > speedup_late,
        "scaling must flatten: early {speedup_early}, late {speedup_late}"
    );
    println!("\nscaling flattens toward the straggler bound — matches Fig 12's shape");

    section("host cost per sweep point");
    bench_n("fig12/320-cores", 5, || {
        let _ = fig12::run_point(320, 42);
    });
}
