//! Bench: the tiered-storage matrix — demote-to-SSD eviction vs the
//! discard-eviction baseline.
//!
//! Prints the matrix comparison table, asserts the acceptance bar —
//! with the working set overflowing RAM but fitting RAM+SSD, tiered
//! serving beats the discard baseline on P99 turnaround at **every**
//! matrix point, moves strictly fewer GPFS bytes, suffers zero
//! checksum mismatches (every stage is checksum-verified by
//! `Residency::commit_stage`; a mismatch aborts the run), and
//! reproduces bit-identically across same-seed runs — then measures
//! host time for both policies. With `XSTAGE_BENCH_JSON` set the
//! measurements emit one JSON point each — CI uploads them per run as
//! the `BENCH_tiers.json` artifact.
//!
//! Run: `cargo bench --bench tiers`

use xstage::experiments::tiers;
use xstage::simtime::flownet::ThroughputMode;
use xstage::staging::service::run_serve;
use xstage::util::bench::{bench_n, section, smoke};
use xstage::units::fmt_bytes;

fn main() {
    section("tiers — demote-to-SSD vs discard eviction");
    let sessions = if smoke() { 8 } else { tiers::SESSIONS };
    let result = tiers::run_with(sessions, 42);
    result.print();

    // Acceptance: at every matrix point (all in the overflow regime by
    // construction), tiered P99 beats discard P99, GPFS traffic
    // strictly drops, the tier actually moved bytes, and same-seed
    // runs are bit-identical.
    let mut saved = 0u64;
    for pt in tiers::matrix() {
        assert!(pt.overflow_regime());
        let (t1, d1) = tiers::run_point(&pt, sessions, 42);
        let (t2, _) = tiers::run_point(&pt, sessions, 42);
        let (tp, dp) = (t1.percentiles.unwrap(), d1.percentiles.unwrap());
        assert!(
            tp.p99 < dp.p99,
            "tiered P99 {} must beat discard P99 {} at {pt:?}",
            tp.p99,
            dp.p99
        );
        assert!(
            t1.staged_bytes < d1.staged_bytes,
            "tiered must move fewer GPFS bytes at {pt:?}: {} vs {}",
            t1.staged_bytes,
            d1.staged_bytes
        );
        assert!(t1.promoted_bytes > 0 && t1.demoted_bytes > 0, "tier idle at {pt:?}");
        assert_eq!(d1.promoted_bytes, 0, "discard baseline promoted at {pt:?}");
        assert_eq!(
            t1.turnaround_secs, t2.turnaround_secs,
            "same-seed tiered runs diverged at {pt:?}"
        );
        assert_eq!(t1.promoted_bytes, t2.promoted_bytes);
        // Neither policy ever sends task input reads to the shared FS.
        assert_eq!(t1.reads.unstaged_bytes, 0);
        saved += d1.staged_bytes - t1.staged_bytes;
    }
    println!(
        "\nall {} matrix points: tiered P99 < discard P99, {} of GPFS re-staging \
         avoided, deterministic, zero checksum mismatches",
        tiers::matrix().len(),
        fmt_bytes(saved),
    );

    section("host-time: tiered serve simulation throughput");
    let pt = tiers::matrix()[0];
    bench_n("tiers/tiered-session-matrix-point", 3, || {
        let out = run_serve(
            tiers::NODES,
            &pt.cfg(true, sessions, 42),
            ThroughputMode::Fast,
        );
        assert_eq!(out.sessions, sessions);
    });
    bench_n("tiers/discard-session-matrix-point", 3, || {
        let out = run_serve(
            tiers::NODES,
            &pt.cfg(false, sessions, 42),
            ThroughputMode::Fast,
        );
        assert_eq!(out.sessions, sessions);
    });
    bench_n("tiers/tiered-session-slow-model", 3, || {
        let out = run_serve(
            tiers::NODES,
            &pt.cfg(true, sessions, 42),
            ThroughputMode::Slow,
        );
        assert_eq!(out.sessions, sessions);
    });
}
