//! Bench: the fleet-scale matrix — seed vs flattened hot paths.
//!
//! Runs every (nodes, sessions) point once per [`PathMode`], asserts
//! the two modes produce bit-identical virtual outcomes, records host
//! time and events/sec for each, and reports resident bytes of state
//! per session and per path via the `StateBytes` reporter. At the
//! largest point (8192 nodes, 10⁴ concurrent sessions) the flattened
//! paths must clear **5x** the seed's events/sec — the tentpole
//! acceptance bar (full mode only; smoke shrinks the matrix to a
//! correctness pass).
//!
//! Also micro-benches the two flattened subsystems in isolation:
//! string-keyed vs interned-id residency lookups, and the fast
//! throughput model settling one giant hub-and-spoke component
//! (hierarchical split vs the flat water-fill it replaces).
//!
//! With `XSTAGE_BENCH_JSON` set every measurement appends one JSON
//! point — CI uploads them per run as the `BENCH_scale.json` artifact.
//!
//! Run: `cargo bench --bench scale`

use std::hint::black_box;

use xstage::experiments::scale;
use xstage::pfs::Blob;
use xstage::simtime::flownet::{Capacity, FlowNet, LinkClass, ThroughputMode};
use xstage::storage::NodeStores;
use xstage::units::{StateBytes, MB};
use xstage::util::bench::{bench_n, record, report_counter, report_state, section, smoke};

fn main() {
    section("scale — fleet matrix: seed vs flattened hot paths");
    let (nodes_sweep, session_sweep): (Vec<u32>, Vec<u32>) = if smoke() {
        (vec![64], vec![200])
    } else {
        (scale::NODE_SWEEP.to_vec(), scale::SESSION_SWEEP.to_vec())
    };
    let mut last_speedup = 0.0f64;
    for (&nodes, &sessions) in nodes_sweep.iter().zip(&session_sweep) {
        // run_point_both asserts the cross-mode virtual identity
        // (finish times, event counts, clock) at every point.
        let (seed_out, flat_out) = scale::run_point_both(nodes, sessions as usize, scale::SEED);
        record(&format!("scale/seed/n{nodes}-s{sessions}"), seed_out.host_secs);
        record(&format!("scale/flat/n{nodes}-s{sessions}"), flat_out.host_secs);
        last_speedup = flat_out.events_per_sec() / seed_out.events_per_sec().max(1e-9);
        println!(
            "  n{nodes}/s{sessions}: {} events; seed {:.0} ev/s, flat {:.0} ev/s \
             ({last_speedup:.1}x); flat wall per sim-second {:.3} ms",
            flat_out.events,
            seed_out.events_per_sec(),
            flat_out.events_per_sec(),
            flat_out.wall_per_sim_sec() * 1e3,
        );
        report_state(
            &format!("scale/sched-per-session/n{nodes}-s{sessions}"),
            flat_out.sched_state,
        );
        report_state(&format!("scale/store-per-path/n{nodes}-s{sessions}"), flat_out.store_state);
        report_state(
            &format!("scale/residency-per-path/n{nodes}-s{sessions}"),
            flat_out.residency_state,
        );
        // Kernel observability: event-heap occupancy peaks and the
        // stale-check economy at this point (wheel backend).
        let k = flat_out.kernel;
        report_counter(
            &format!("scale/heap-peak-depth/n{nodes}-s{sessions}"),
            k.heap.peak_depth as u64,
        );
        report_counter(
            &format!("scale/heap-peak-wheel/n{nodes}-s{sessions}"),
            k.heap.peak_wheel as u64,
        );
        report_counter(
            &format!("scale/heap-peak-overflow/n{nodes}-s{sessions}"),
            k.heap.peak_overflow as u64,
        );
        report_counter(
            &format!("scale/stale-checks-reclaimed/n{nodes}-s{sessions}"),
            k.stale_checks_reclaimed,
        );
        report_counter(&format!("scale/stale-check-pops/n{nodes}-s{sessions}"), k.stale_check_pops);
        // Post-drain footprint stays bounded per session regardless of
        // fleet size (completed sessions hold no graph storage).
        assert!(
            flat_out.sched_state.per_unit() < 1024,
            "resident {} B/session after drain",
            flat_out.sched_state.per_unit()
        );
    }
    if !smoke() {
        assert!(
            last_speedup >= 5.0,
            "flattened hot paths must clear 5x the seed events/sec at the largest \
             matrix point, got {last_speedup:.1}x"
        );
        println!("\nlargest point speedup {last_speedup:.1}x >= 5x: acceptance bar cleared");
    }

    section("scale — residency lookups: string-keyed vs interned id");
    let paths_n = if smoke() { 256 } else { 4096 };
    let mut stores = NodeStores::new();
    let paths: Vec<String> = (0..paths_n)
        .map(|i| format!("/projects/HEDM/layer{}/f{i:05}.bin", i % 7))
        .collect();
    for (i, p) in paths.iter().enumerate() {
        stores.write_range(0, 63, p, Blob::synthetic(MB, i as u64));
    }
    let ids: Vec<u32> = paths.iter().map(|p| stores.path_id(p).unwrap()).collect();
    let by_string = bench_n(&format!("scale/coverage-string-{paths_n}"), 5, || {
        for p in &paths {
            black_box(stores.coverage_of(p));
        }
    });
    let by_id = bench_n(&format!("scale/coverage-id-{paths_n}"), 5, || {
        for &id in &ids {
            black_box(stores.coverage_of_id(id));
        }
    });
    report_state(
        "scale/stores-per-path",
        StateBytes::new(stores.state_bytes(), stores.interned_paths() as u64),
    );
    if !smoke() {
        assert!(
            by_id.median < by_string.median,
            "id coverage ({}) must beat string coverage ({})",
            by_id.median,
            by_string.median
        );
    }

    section("scale — flownet: giant hub-and-spoke component settle");
    // One backplane-class hub feeding n independent spokes: with slack
    // on the hub the fast model splits the giant component per spoke
    // group, so the settle and every later completion touch one spoke,
    // not all n. comp_count == n is the witness that the split took.
    let spokes = if smoke() { 300 } else { 2048 };
    bench_n(&format!("scale/giant-settle-{spokes}"), 3, || {
        let mut net = FlowNet::with_mode(ThroughputMode::Fast);
        let hub = net.add_link_classed(
            "hub",
            Capacity::Fixed(4.0 * spokes as f64 * 1e6),
            LinkClass::Backplane,
        );
        let mut flows = Vec::with_capacity(spokes);
        for i in 0..spokes {
            let spoke =
                net.add_link_classed(format!("s{i}"), Capacity::Fixed(1e6), LinkClass::Ion);
            flows.push(net.start(vec![spoke, hub], 1, 10_000 + 7 * i as u64));
        }
        net.recompute();
        assert_eq!(net.comp_count(), spokes, "hierarchical split must take");
        // Churn: each completion re-settles only its own spoke group.
        for &f in flows.iter().take(spokes / 4) {
            net.complete(f);
            net.recompute();
        }
        assert_eq!(net.comp_count(), spokes - spokes / 4);
    });
}
