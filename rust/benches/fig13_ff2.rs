//! Bench: regenerate Fig 13 (FF-HEDM stage 2 makespan scaling — 4,109
//! grain tasks, 5-25 s each, on Orthros).
//!
//! Run: `cargo bench --bench fig13_ff2`

use xstage::experiments::fig13;
use xstage::util::bench::{bench_n, section};

fn main() {
    section("Fig 13 — virtual results (4,109 tasks on Orthros)");
    let result = fig13::default();
    result.print();

    let pts = result.series_named("makespan s").unwrap();
    // Shape: near-linear scaling (short tasks pack well — the contrast
    // with Fig 12).
    let speedup = pts[0].1 / pts.last().unwrap().1;
    let ideal = pts.last().unwrap().0 / pts[0].0;
    assert!(
        speedup > 0.85 * ideal,
        "FF2 should scale near-ideally: {speedup:.2}x vs ideal {ideal:.2}x"
    );
    println!("\nspeedup {speedup:.2}x vs ideal {ideal:.2}x — near-linear, matches Fig 13");

    section("host cost per sweep point");
    bench_n("fig13/320-cores", 5, || {
        let _ = fig13::run_point(320, 43);
    });
}
