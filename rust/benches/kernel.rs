//! Bench: the event-core kernel — bucketed timer wheel + eager
//! stale-check reclamation vs the seed binary-heap backend, and the
//! parallel experiment-matrix runner.
//!
//! Two acceptance bars (full mode only; smoke shrinks to a correctness
//! pass):
//!
//! - **kernel speedup** — on a churn-heavy fleet point (chained tasks,
//!   every completion re-settles live flow components, so the seed
//!   heap drowns in stale `FlowCheck` timers) the wheel backend must
//!   clear **2x** the seed backend's useful events/sec, with a
//!   bit-identical virtual outcome (same per-session finish times,
//!   same useful event count — raw event counts differ only by the
//!   stale pops the wheel reclaims eagerly);
//! - **parallel runner** — fanning the serve matrix across 4 workers
//!   must cut wall-clock **2x** vs the serial path while producing a
//!   byte-identical table and series.
//!
//! Also cross-checks a chaos point (kills retire components mid-run,
//! the nastiest reclamation path) across both backends.
//!
//! With `XSTAGE_BENCH_JSON` set the measurements emit one JSON point
//! each — CI uploads them per run as the `BENCH_kernel.json` artifact.
//!
//! Run: `cargo bench --bench kernel`

use std::time::Instant;

use xstage::experiments::scale::{self, PathMode};
use xstage::experiments::{chaos, serve};
use xstage::simtime::flownet::ThroughputMode;
use xstage::simtime::heap::HeapKind;
use xstage::staging::service::run_serve_kernel;
use xstage::util::bench::{record, report_counter, section, smoke};

fn main() {
    section("kernel — wheel vs seed event heap on a churn-heavy fleet point");
    let (nodes, sessions) = if smoke() { (64, 200) } else { (512, 2_000) };
    let seed_out = scale::run_point_kernel(nodes, sessions, PathMode::Flat, scale::SEED, HeapKind::Seed);
    let wheel_out =
        scale::run_point_kernel(nodes, sessions, PathMode::Flat, scale::SEED, HeapKind::Wheel);

    // Bit-identical virtual outcome across backends: the wheel may
    // reclaim timers the seed pops as no-ops, but every session
    // finishes at the same virtual instant and the useful event
    // stream is the same.
    assert_eq!(
        seed_out.finished, wheel_out.finished,
        "per-session finish times diverged across event-heap backends"
    );
    assert_eq!(
        seed_out.useful_events(),
        wheel_out.useful_events(),
        "useful event counts diverged across event-heap backends"
    );
    assert_eq!(wheel_out.kernel.stale_checks_reclaimed + wheel_out.kernel.stale_check_pops,
        seed_out.kernel.stale_check_pops,
        "every seed stale pop must be a wheel reclaim (or an unreclaimed pop)");

    record(&format!("kernel/seed-heap/n{nodes}-s{sessions}"), seed_out.host_secs);
    record(&format!("kernel/wheel/n{nodes}-s{sessions}"), wheel_out.host_secs);
    report_counter("kernel/seed/heap-peak-depth", seed_out.kernel.heap.peak_depth as u64);
    report_counter("kernel/wheel/heap-peak-depth", wheel_out.kernel.heap.peak_depth as u64);
    report_counter("kernel/wheel/heap-peak-wheel", wheel_out.kernel.heap.peak_wheel as u64);
    report_counter("kernel/wheel/heap-peak-overflow", wheel_out.kernel.heap.peak_overflow as u64);
    report_counter("kernel/seed/stale-check-pops", seed_out.kernel.stale_check_pops);
    report_counter("kernel/wheel/stale-check-pops", wheel_out.kernel.stale_check_pops);
    report_counter("kernel/wheel/stale-checks-reclaimed", wheel_out.kernel.stale_checks_reclaimed);

    let seed_rate = seed_out.useful_events() as f64 / seed_out.host_secs.max(1e-9);
    let wheel_rate = wheel_out.useful_events() as f64 / wheel_out.host_secs.max(1e-9);
    let speedup = wheel_rate / seed_rate.max(1e-9);
    println!(
        "  n{nodes}/s{sessions}: {} useful events; seed {:.0} ev/s (peak heap {}), \
         wheel {:.0} ev/s (peak {} = wheel {} + overflow {}); {speedup:.1}x",
        wheel_out.useful_events(),
        seed_rate,
        seed_out.kernel.heap.peak_depth,
        wheel_rate,
        wheel_out.kernel.heap.peak_depth,
        wheel_out.kernel.heap.peak_wheel,
        wheel_out.kernel.heap.peak_overflow,
    );
    if !smoke() {
        assert!(
            speedup >= 2.0,
            "wheel backend must clear 2x the seed heap's useful events/sec on the \
             churn-heavy point, got {speedup:.1}x"
        );
        println!("\nkernel speedup {speedup:.1}x >= 2x: acceptance bar cleared");
    }

    section("kernel — chaos point (mid-run component retirement) across backends");
    let csessions = if smoke() { 8 } else { chaos::SESSIONS };
    let failures = *chaos::FAILURE_SWEEP.last().unwrap();
    let cfg = chaos::cfg(failures, true, csessions, chaos::SEED);
    let t0 = Instant::now();
    let cs = run_serve_kernel(chaos::NODES, &cfg, ThroughputMode::Fast, HeapKind::Seed);
    let seed_secs = t0.elapsed().as_secs_f64();
    let t1 = Instant::now();
    let cw = run_serve_kernel(chaos::NODES, &cfg, ThroughputMode::Fast, HeapKind::Wheel);
    let wheel_secs = t1.elapsed().as_secs_f64();
    assert_eq!(
        cs.turnaround_secs, cw.turnaround_secs,
        "chaos turnarounds diverged across event-heap backends"
    );
    assert_eq!(cs.useful_events(), cw.useful_events(), "chaos useful events diverged");
    assert_eq!(cs.lost_tasks, cw.lost_tasks);
    record("kernel/chaos-seed-heap", seed_secs);
    record("kernel/chaos-wheel", wheel_secs);
    report_counter("kernel/chaos-wheel/stale-checks-reclaimed", cw.kernel.stale_checks_reclaimed);

    section("kernel — parallel matrix runner: serial vs 4 workers");
    let psessions = if smoke() { 6 } else { serve::SESSIONS };
    let t0 = Instant::now();
    let serial = serve::run_with_jobs(psessions, 42, 1);
    let serial_secs = t0.elapsed().as_secs_f64();
    let t1 = Instant::now();
    let par = serve::run_with_jobs(psessions, 42, 4);
    let par_secs = t1.elapsed().as_secs_f64();
    assert_eq!(serial.table.rows, par.table.rows, "parallel serve table diverged");
    assert_eq!(serial.series, par.series, "parallel serve series diverged");
    record("kernel/serve-matrix-jobs1", serial_secs);
    record("kernel/serve-matrix-jobs4", par_secs);
    let cut = serial_secs / par_secs.max(1e-9);
    println!("  serve matrix: serial {serial_secs:.2}s, 4 workers {par_secs:.2}s ({cut:.1}x)");
    if !smoke() {
        assert!(
            cut >= 2.0,
            "4 workers must cut the serve-matrix wall-clock 2x, got {cut:.1}x"
        );
        println!("\nparallel runner cut {cut:.1}x >= 2x: acceptance bar cleared");
    }
}
