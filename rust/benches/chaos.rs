//! Bench: serving under chaos — node-failure injection with recovery.
//!
//! Prints the failure-count x requeue-policy matrix, then asserts the
//! acceptance bar:
//!
//! - **bounded degradation** — at every injected failure count in the
//!   sweep (which kills far *denser* than the MTBF-calibrated fleet
//!   rate, so the bound holds a fortiori at realistic rates), the P99
//!   turnaround stays within 2x of the same policy's zero-failure
//!   control;
//! - **no task loss, no duplication** — every session completes
//!   (asserted inside `run_serve`) and the whole chaotic run is
//!   bit-reproducible across two same-seed runs;
//! - **checksum-clean recovery** — every recovery stage content-verifies
//!   its replicas against the shared-FS originals before committing
//!   (`Residency::commit_stage` panics the run otherwise), and no task
//!   read ever falls back to the shared FS.
//!
//! With `XSTAGE_BENCH_JSON` set the measurements emit one JSON point
//! each — CI uploads them per run as the `BENCH_chaos.json` artifact.
//!
//! Run: `cargo bench --bench chaos`

use xstage::experiments::chaos;
use xstage::util::bench::{bench_n, section, smoke};

fn main() {
    section("chaos — node-failure injection over staged serving");
    let sessions = if smoke() { 8 } else { chaos::SESSIONS };
    chaos::run_with(sessions, chaos::SEED).print();

    // Acceptance: bounded P99 degradation vs the zero-failure control,
    // deterministic replay, and recovery that never touches the shared
    // FS for task reads.
    for stealing in [false, true] {
        let calm = chaos::run_point(0, stealing, sessions, chaos::SEED);
        let calm_p99 = calm.percentiles.unwrap().p99;
        assert_eq!(calm.node_failures, 0);
        assert_eq!(calm.lost_tasks, 0);
        for &failures in chaos::FAILURE_SWEEP {
            let out = chaos::run_point(failures, stealing, sessions, chaos::SEED);
            assert_eq!(out.node_failures, failures);
            let p99 = out.percentiles.unwrap().p99;
            assert!(
                p99 <= 2.0 * calm_p99,
                "P99 degraded beyond 2x at {failures} failures (stealing {stealing}): \
                 {p99:.1}s vs calm {calm_p99:.1}s"
            );
            assert_eq!(
                out.reads.unstaged_bytes, 0,
                "recovery let a task read fall back to the shared FS"
            );
            let again = chaos::run_point(failures, stealing, sessions, chaos::SEED);
            assert_eq!(
                out.turnaround_secs, again.turnaround_secs,
                "same-seed chaotic runs diverged at {failures} failures"
            );
            assert_eq!(out.lost_tasks, again.lost_tasks);
            assert_eq!(out.copied_bytes, again.copied_bytes);
        }
    }
    println!(
        "\nall {} failure counts x both policies: P99 <= 2x calm, \
         deterministic, checksum-clean recovery",
        chaos::FAILURE_SWEEP.len()
    );

    section("host-time: chaotic serve simulation throughput");
    let failures = *chaos::FAILURE_SWEEP.last().unwrap();
    bench_n("chaos/fifo-requeue-point", 3, || {
        let out = chaos::run_point(failures, false, sessions, chaos::SEED);
        assert_eq!(out.sessions, sessions);
    });
    bench_n("chaos/work-stealing-point", 3, || {
        let out = chaos::run_point(failures, true, sessions, chaos::SEED);
        assert_eq!(out.sessions, sessions);
    });
    bench_n("chaos/zero-failure-control", 3, || {
        let out = chaos::run_point(0, true, sessions, chaos::SEED);
        assert_eq!(out.sessions, sessions);
    });
}
