//! Bench: streaming detector ingest vs write-to-GPFS-then-stage.
//!
//! Prints the cadence x RAM-slice x landing-mode matrix, then asserts
//! the acceptance bar:
//!
//! - **streaming wins ttfr everywhere** — at every matrix point the
//!   streaming detector's time-to-first-result beats the GPFS-first
//!   baseline's (the baseline pays the shared-FS leg per frame before
//!   the data is addressable, then a full-dataset stage before any
//!   session starts);
//! - **zero-rate identity** — a detector armed with zero frames
//!   reproduces the plain staged service bit-for-bit;
//! - **conservation and determinism** — every emitted frame lands in
//!   exactly one tier, no task read ever falls back to the shared FS,
//!   and every point is bit-reproducible across two same-seed runs.
//!
//! With `XSTAGE_BENCH_JSON` set the measurements emit one JSON point
//! each — CI uploads them per run as the `BENCH_ingest.json` artifact.
//!
//! Run: `cargo bench --bench ingest`

use xstage::experiments::ingest;
use xstage::simtime::flownet::ThroughputMode;
use xstage::staging::{run_serve, IngestCfg, IngestMode, ServiceCfg};
use xstage::util::bench::{bench_n, section, smoke};

fn main() {
    section("ingest — streaming detector vs GPFS-first baseline");
    let sessions = if smoke() { 3 } else { ingest::SESSIONS };
    ingest::run_with(sessions, ingest::SEED).print();

    // Acceptance: streaming wins time-to-first-result at every point,
    // frames are conserved, and every point replays bit-identically.
    for &gap in ingest::GAP_SWEEP {
        for &slice in ingest::SLICE_SWEEP {
            let s = ingest::run_point(gap, slice, IngestMode::Stream, sessions, ingest::SEED);
            let g = ingest::run_point(gap, slice, IngestMode::GpfsFirst, sessions, ingest::SEED);
            let si = s.ingest.clone().expect("stream point lost its detector");
            let gi = g.ingest.expect("baseline point lost its detector");
            assert_eq!(si.ram_frames + si.ssd_frames + si.gpfs_frames, ingest::FRAMES);
            assert_eq!(gi.gpfs_frames, ingest::FRAMES);
            let st = si.first_result_secs.expect("no session read the live dataset");
            let gt = gi.first_result_secs.expect("no session read the live dataset");
            assert!(
                st < gt,
                "streaming lost ttfr at gap {gap} slice {slice}: {st:.2}s vs {gt:.2}s"
            );
            assert_eq!(
                s.reads.unstaged_bytes, 0,
                "a live-frame read fell back to the shared FS"
            );
            let again = ingest::run_point(gap, slice, IngestMode::Stream, sessions, ingest::SEED);
            assert_eq!(
                s.turnaround_secs, again.turnaround_secs,
                "same-seed ingest runs diverged at gap {gap} slice {slice}"
            );
            assert_eq!(Some(si), again.ingest);
        }
    }
    println!(
        "\nall {} matrix points: streaming ttfr < gpfs-first ttfr, \
         frames conserved, deterministic",
        ingest::GAP_SWEEP.len() * ingest::SLICE_SWEEP.len()
    );

    // Acceptance: a zero-rate detector is the plain service, bit for
    // bit — arming the ingest path must cost nothing when idle.
    let base = || ServiceCfg { sessions, ..Default::default() };
    let mut armed = base();
    armed.ingest = Some(IngestCfg { frames: 0, ..Default::default() });
    let a = run_serve(2, &armed, ThroughputMode::Fast);
    let b = run_serve(2, &base(), ThroughputMode::Fast);
    assert!(a.ingest.is_none(), "zero frames means no detector outcome");
    assert_eq!(a.turnaround_secs, b.turnaround_secs);
    assert_eq!(a.virtual_secs, b.virtual_secs);
    assert_eq!(a.staged_bytes, b.staged_bytes);
    println!("zero-rate detector reproduces the plain service bit-for-bit");

    section("host-time: ingest serve simulation throughput");
    let hot = ingest::GAP_SWEEP[0];
    let roomy = ingest::SLICE_SWEEP[0];
    let tight = *ingest::SLICE_SWEEP.last().unwrap();
    bench_n("ingest/stream-roomy-point", 3, || {
        let out = ingest::run_point(hot, roomy, IngestMode::Stream, sessions, ingest::SEED);
        assert_eq!(out.sessions, sessions);
    });
    bench_n("ingest/stream-tight-point", 3, || {
        let out = ingest::run_point(hot, tight, IngestMode::Stream, sessions, ingest::SEED);
        assert_eq!(out.sessions, sessions);
    });
    bench_n("ingest/gpfs-first-point", 3, || {
        let out = ingest::run_point(hot, tight, IngestMode::GpfsFirst, sessions, ingest::SEED);
        assert_eq!(out.sessions, sessions);
    });
}
