"""Shared HEDM geometry: physics constants, reciprocal lattice, detector.

This module is the single source of truth for the diffraction geometry
used by the L1 Pallas kernels, the L2 JAX model, the pure-jnp reference
oracle, and (via the artifact manifest) the Rust detector simulator and
indexer. Keeping every constant here guarantees that the synthetic
detector (Rust), the reduction pipeline (L2), and the orientation fit
(L1) agree on the forward model.

Physics (far-field HEDM, monochromatic rotating-crystal method):

  - Incident beam along +x with wavevector k = 2*pi/lambda.
  - Sample rotates about the lab z axis by omega.
  - A reciprocal-lattice vector G (crystal frame) diffracts at the
    omega where the elastic condition |k_in + g| = |k_in| holds, i.e.

        g_x(omega) = -lambda * |g|^2 / (4*pi)

    with g(omega) = Rz(omega) * R_crystal * G.  Writing the x component
    as A*cos(omega + phi), A = sqrt(gx^2 + gy^2), phi = atan2(gy, gx),
    the condition has two solutions (Friedel pair) when |t| <= 1:

        omega = +/- acos(t) - phi,   t = -lambda |g|^2 / (4 pi A)

  - The scattered wavevector is k_out = k_in + g(omega*); a far-field
    detector at distance DET_DIST along +x records the spot at

        u = DET_DIST * k_out_y / k_out_x   (horizontal, micrometres)
        v = DET_DIST * k_out_z / k_out_x   (vertical,   micrometres)

    converted to pixels by PIXEL_SIZE.

These are the same equations the paper's FF-HEDM indexing code (MIDAS
lineage, refs [17], [18]) implements in C; we use one shared constant
set so Rust and JAX agree bit-for-bit up to float error.
"""

from __future__ import annotations

import dataclasses
import math

import numpy as np

# ---------------------------------------------------------------------------
# Experiment constants (the "parameter file" of Fig 8).
# ---------------------------------------------------------------------------

#: X-ray wavelength in Angstrom (71.68 keV, E > 50 keV per the paper).
WAVELENGTH = 0.172979

#: Cubic lattice parameter in Angstrom (FCC gold, the Fig 2 sample).
LATTICE_A = 4.0782

#: Sample-to-detector distance, micrometres. The paper's FF setup is
#: "up to 1 m" with a 2048-pixel panel; our default panel is 512 px
#: (see DEFAULT_FRAME), so the distance is scaled to 0.25 m to keep the
#: same angular acceptance (all rings through hmax=3 on-panel).
DET_DIST = 2.5e5

#: Detector pixel size, micrometres (FF: "~200 um pixel size").
PIXEL_SIZE = 200.0

#: Detector panel size in pixels (square). The paper's detectors produce
#: 8 MB frames (2048x2048 u16); the default artifact size is reduced so
#: that interpret-mode Pallas stays fast. The Rust detector simulator
#: scales all byte accounting back to the paper's 8 MB frames.
DEFAULT_FRAME = 512

#: Number of rotation steps per layer ("360 to 1,440 angles").
DEFAULT_OMEGA_STEPS = 360

#: Omega range covered by a scan, degrees.
OMEGA_SPAN = 360.0

#: Maximum reciprocal-lattice vectors used for simulation/fitting.
#: 58 = the complete {111},{200},{220},{311},{222} shells; gvectors()
#: only admits whole |G| shells so the set stays inversion-symmetric.
S_MAX = 58

#: Maximum observed spots per fit (padded; mask marks the valid prefix).
O_MAX = 512

#: Orientation candidates scored per kernel invocation.
B_BATCH = 256

#: Weight converting omega degrees into pixel-equivalent distance for
#: the spot-matching metric (a spot is (u_px, v_px, omega * OMEGA_WEIGHT)).
OMEGA_WEIGHT = 4.0

#: Match tolerance in the weighted spot metric, pixels.
MATCH_TOL = 6.0

#: Dark-field stack depth for the median dark frame.
DARK_FRAMES = 8

#: Reduction thresholds (counts above dark median / LoG response).
INTENSITY_THRESHOLD = 80.0
LOG_THRESHOLD = 12.0

#: LoG filter width.
LOG_SIGMA = 1.2
LOG_HALF = 2  # 5x5 kernel


@dataclasses.dataclass(frozen=True)
class Config:
    """Bundle of geometry constants, overridable for tests."""

    wavelength: float = WAVELENGTH
    lattice_a: float = LATTICE_A
    det_dist: float = DET_DIST
    pixel_size: float = PIXEL_SIZE
    frame: int = DEFAULT_FRAME
    omega_steps: int = DEFAULT_OMEGA_STEPS
    s_max: int = S_MAX
    o_max: int = O_MAX
    b_batch: int = B_BATCH
    omega_weight: float = OMEGA_WEIGHT
    match_tol: float = MATCH_TOL
    dark_frames: int = DARK_FRAMES
    intensity_threshold: float = INTENSITY_THRESHOLD
    log_threshold: float = LOG_THRESHOLD
    log_sigma: float = LOG_SIGMA
    log_half: int = LOG_HALF

    @property
    def k_in(self) -> float:
        """Incident wavevector magnitude, 1/Angstrom."""
        return 2.0 * math.pi / self.wavelength

    @property
    def center(self) -> float:
        """Beam-centre pixel (square panel, centred)."""
        return self.frame / 2.0


DEFAULT_CONFIG = Config()


# ---------------------------------------------------------------------------
# Reciprocal lattice.
# ---------------------------------------------------------------------------


def fcc_allowed(h: int, k: int, l: int) -> bool:
    """FCC structure-factor selection rule: h,k,l all even or all odd."""
    parities = {h % 2, k % 2, l % 2}
    return len(parities) == 1


def gvectors(cfg: Config = DEFAULT_CONFIG, hmax: int = 3) -> np.ndarray:
    """Reciprocal-lattice vectors (s_max, 3), f32, sorted by |G| then hkl.

    Cubic: G = (2*pi / a) * (h, k, l). Only FCC-allowed reflections are
    kept, and only *complete* |G| shells are admitted (so the set is
    inversion-symmetric: Friedel mates are never split by truncation).
    The array is zero-padded to cfg.s_max rows (padding marked by
    gvector_mask) so artifact shapes stay static.
    """
    out = []
    for h in range(-hmax, hmax + 1):
        for k in range(-hmax, hmax + 1):
            for l in range(-hmax, hmax + 1):
                if h == 0 and k == 0 and l == 0:
                    continue
                if not fcc_allowed(h, k, l):
                    continue
                norm2 = h * h + k * k + l * l
                out.append((norm2, h, k, l))
    out.sort()
    kept: list[tuple[int, int, int]] = []
    i = 0
    while i < len(out):
        # Extend by the whole shell (equal |G|^2) or stop.
        j = i
        while j < len(out) and out[j][0] == out[i][0]:
            j += 1
        if len(kept) + (j - i) > cfg.s_max:
            break
        kept.extend((h, k, l) for _, h, k, l in out[i:j])
        i = j
    scale = 2.0 * math.pi / cfg.lattice_a
    vecs = np.array(kept, dtype=np.float32) * scale
    if vecs.shape[0] < cfg.s_max:
        pad = np.zeros((cfg.s_max - vecs.shape[0], 3), dtype=np.float32)
        vecs = np.concatenate([vecs, pad], axis=0)
    return vecs


def gvector_mask(cfg: Config = DEFAULT_CONFIG, hmax: int = 3) -> np.ndarray:
    """Validity mask (s_max,) for zero-padded rows of :func:`gvectors`."""
    g = gvectors(cfg, hmax)
    return (np.linalg.norm(g, axis=1) > 1e-6).astype(np.float32)


# ---------------------------------------------------------------------------
# Rotations (numpy reference; jnp versions live in kernels/ref.py).
# ---------------------------------------------------------------------------


def euler_to_matrix(phi1: float, capphi: float, phi2: float) -> np.ndarray:
    """Bunge ZXZ Euler angles (radians) -> 3x3 rotation matrix (f64).

    R = Rz(phi1) @ Rx(capphi) @ Rz(phi2); the convention used across the
    Rust simulator and the JAX kernels.
    """
    c1, s1 = math.cos(phi1), math.sin(phi1)
    cP, sP = math.cos(capphi), math.sin(capphi)
    c2, s2 = math.cos(phi2), math.sin(phi2)
    rz1 = np.array([[c1, -s1, 0], [s1, c1, 0], [0, 0, 1]])
    rx = np.array([[1, 0, 0], [0, cP, -sP], [0, sP, cP]])
    rz2 = np.array([[c2, -s2, 0], [s2, c2, 0], [0, 0, 1]])
    return rz1 @ rx @ rz2


def simulate_spots(
    euler: tuple[float, float, float],
    cfg: Config = DEFAULT_CONFIG,
    hmax: int = 3,
) -> np.ndarray:
    """Forward-simulate the (u_px, v_px, omega_deg) spot list for one grain.

    Pure-numpy oracle used by tests and mirrored by the Rust detector
    simulator (rust/src/hedm/geometry.rs). Returns an (n, 3) f64 array of
    spots that land on the detector panel.
    """
    rot = euler_to_matrix(*euler)
    gv = gvectors(cfg, hmax).astype(np.float64)
    mask = gvector_mask(cfg, hmax) > 0.5
    lam = cfg.wavelength
    k = cfg.k_in
    spots = []
    for keep, g0 in zip(mask, gv):
        if not keep:
            continue
        g = rot @ g0
        gsq = float(g @ g)
        a = math.hypot(g[0], g[1])
        if a < 1e-12:
            continue
        t = -lam * gsq / (4.0 * math.pi) / a
        if abs(t) > 1.0:
            continue
        phi = math.atan2(g[1], g[0])
        for sign in (1.0, -1.0):
            omega = sign * math.acos(t) - phi
            # wrap to [-pi, pi)
            omega = (omega + math.pi) % (2.0 * math.pi) - math.pi
            co, so = math.cos(omega), math.sin(omega)
            gxr = g[0] * co - g[1] * so
            gyr = g[0] * so + g[1] * co
            kfx = k + gxr
            kfy = gyr
            kfz = g[2]
            if kfx <= 0.0:
                continue
            u = cfg.det_dist * kfy / kfx / cfg.pixel_size + cfg.center
            v = cfg.det_dist * kfz / kfx / cfg.pixel_size + cfg.center
            if 0.0 <= u < cfg.frame and 0.0 <= v < cfg.frame:
                spots.append((u, v, math.degrees(omega)))
    return np.array(spots, dtype=np.float64).reshape(-1, 3)


def log_kernel_2d(sigma: float = LOG_SIGMA, half: int = LOG_HALF) -> np.ndarray:
    """(2*half+1)^2 Laplacian-of-Gaussian filter, zero-mean, f32.

    Sign convention: positive response at the centre of a *bright* blob
    (i.e. the negated classic LoG), so thresholding is `response > thr`.
    """
    n = 2 * half + 1
    y, x = np.mgrid[-half : half + 1, -half : half + 1].astype(np.float64)
    r2 = x * x + y * y
    s2 = sigma * sigma
    log = (r2 - 2.0 * s2) / (s2 * s2) * np.exp(-r2 / (2.0 * s2))
    log -= log.mean()
    # negate: bright blob centre -> positive response
    return (-log).astype(np.float32).reshape(n, n)
