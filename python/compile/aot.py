"""AOT bridge: lower the L2 model to HLO *text* artifacts for Rust.

HLO text (not `.serialize()`d HloModuleProto) is the interchange
format: jax >= 0.5 emits protos with 64-bit instruction ids which the
`xla` crate's xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`);
the text parser reassigns ids, so text round-trips cleanly. Lowered
with return_tuple=True; the Rust side unwraps with to_tupleN().

Usage (from python/): python -m compile.aot --out ../artifacts
Writes one .hlo.txt per entry point plus manifest.json describing the
shapes and the geometry constants, which the Rust side cross-checks
against its own mirrored constants (rust/src/hedm/geometry.rs).

`make artifacts` is the only place Python runs; the Rust binary is
self-contained afterwards.
"""

from __future__ import annotations

import argparse
import dataclasses
import hashlib
import json
import pathlib

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import geometry, model


def to_hlo_text(lowered) -> str:
    """jax Lowered -> XLA HLO text via stablehlo (see module docstring)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def entry_points(cfg: geometry.Config):
    """Name -> (callable, example args). Shapes are static per config."""
    h = w = cfg.frame
    k = cfg.dark_frames
    b = cfg.b_batch
    s = cfg.s_max
    o = cfg.o_max
    return {
        "dark_median": (
            model.dark_median,
            [_spec((k, h, w))],
        ),
        "reduce_frame": (
            lambda frame, dark: model.reduce_frame(frame, dark, cfg),
            [_spec((h, w)), _spec((h, w))],
        ),
        "peak_search": (
            lambda mask, intensity: model.peak_search(mask, intensity, cfg),
            [_spec((h, w)), _spec((h, w))],
        ),
        "fit_orientation": (
            lambda e, g, gm, ob, om: model.fit_orientation(e, g, gm, ob, om, cfg),
            [_spec((b, 3)), _spec((s, 3)), _spec((s,)), _spec((o, 3)), _spec((o,))],
        ),
        # Tiny smoke computation for runtime unit tests: (x + y, x * y).
        "smoke_addmul": (
            lambda x, y: (x + y, x * y),
            [_spec((4,)), _spec((4,))],
        ),
    }


def build(out_dir: pathlib.Path, cfg: geometry.Config) -> dict:
    out_dir.mkdir(parents=True, exist_ok=True)
    manifest: dict = {
        "config": dataclasses.asdict(cfg),
        "gvectors": geometry.gvectors(cfg).tolist(),
        "gvector_mask": geometry.gvector_mask(cfg).tolist(),
        "entry_points": {},
    }
    for name, (fn, args) in entry_points(cfg).items():
        lowered = jax.jit(fn).lower(*args)
        text = to_hlo_text(lowered)
        path = out_dir / f"{name}.hlo.txt"
        path.write_text(text)
        # Execute on example zeros to record output arity/shapes.
        outs = jax.eval_shape(fn, *args)
        flat, _ = jax.tree.flatten(outs)
        manifest["entry_points"][name] = {
            "file": path.name,
            "sha256": hashlib.sha256(text.encode()).hexdigest(),
            "inputs": [
                {"shape": list(a.shape), "dtype": str(a.dtype)} for a in args
            ],
            "outputs": [
                {"shape": list(o.shape), "dtype": str(o.dtype)} for o in flat
            ],
        }
        print(f"  {name}: {len(text)} chars, {len(flat)} outputs")
    (out_dir / "manifest.json").write_text(json.dumps(manifest, indent=1))
    return manifest


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="artifact directory")
    ap.add_argument("--frame", type=int, default=geometry.DEFAULT_FRAME)
    args = ap.parse_args()
    cfg = geometry.Config(frame=args.frame)
    out = pathlib.Path(args.out)
    print(f"lowering artifacts to {out.resolve()} (frame={cfg.frame})")
    build(out, cfg)
    print("aot done")


if __name__ == "__main__":
    main()
