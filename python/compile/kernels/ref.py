"""Pure-jnp reference oracles for the Pallas kernels.

Every kernel in this package has an independently written counterpart
here; python/tests asserts allclose between the two. The references are
deliberately *naive* (sort-based median, per-candidate vmap over scalar
geometry) so that a bug shared between kernel and oracle is unlikely.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from .. import geometry


def median_threshold_ref(
    stack: jnp.ndarray, dark: jnp.ndarray, *, threshold: float
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Sort-based median over the 9-plane stack, then subtract/threshold."""
    med = jnp.median(stack, axis=0)
    sub = jnp.maximum(med - dark, 0.0)
    mask = (sub > threshold).astype(jnp.float32)
    return sub, mask


def _rotmat_single(e):
    """Bunge ZXZ rotation matrix from one (3,) Euler triple."""
    c1, s1 = jnp.cos(e[0]), jnp.sin(e[0])
    cp, sp = jnp.cos(e[1]), jnp.sin(e[1])
    c2, s2 = jnp.cos(e[2]), jnp.sin(e[2])
    rz1 = jnp.array([[c1, -s1, 0.0], [s1, c1, 0.0], [0.0, 0.0, 1.0]])
    rx = jnp.array([[1.0, 0.0, 0.0], [0.0, cp, -sp], [0.0, sp, cp]])
    rz2 = jnp.array([[c2, -s2, 0.0], [s2, c2, 0.0], [0.0, 0.0, 1.0]])
    return rz1 @ rx @ rz2


def _spots_single(e, gvec, gmask, cfg: geometry.Config):
    """Predicted spots for ONE orientation, scalar-geometry formulation."""
    lam = cfg.wavelength
    rot = _rotmat_single(e)
    g = (rot @ gvec.T).T  # (S, 3)
    gx, gy, gz = g[:, 0], g[:, 1], g[:, 2]
    gsq = gx**2 + gy**2 + gz**2
    a = jnp.sqrt(gx**2 + gy**2)
    t = -lam * gsq / (4.0 * math.pi) / jnp.maximum(a, 1e-12)
    reachable = (jnp.abs(t) <= 1.0) & (a > 1e-8) & (gmask > 0.5)
    phi = jnp.arctan2(gy, gx)
    acos_t = jnp.arccos(jnp.clip(t, -1.0, 1.0))

    def branch(sign):
        omega = sign * acos_t - phi
        omega = jnp.mod(omega + math.pi, 2 * math.pi) - math.pi
        gxr = gx * jnp.cos(omega) - gy * jnp.sin(omega)
        gyr = gx * jnp.sin(omega) + gy * jnp.cos(omega)
        kfx = cfg.k_in + gxr
        ok = reachable & (kfx > 0.0)
        kfx_s = jnp.where(ok, kfx, 1.0)
        u = cfg.det_dist * gyr / kfx_s / cfg.pixel_size + cfg.center
        v = cfg.det_dist * gz / kfx_s / cfg.pixel_size + cfg.center
        ok = ok & (u >= 0) & (u < cfg.frame) & (v >= 0) & (v < cfg.frame)
        w = jnp.degrees(omega) * cfg.omega_weight
        spot = jnp.stack([u, v, w], axis=-1)
        spot = jnp.where(ok[:, None], spot, -1.0e6)
        return spot, ok.astype(jnp.float32)

    sp, vp = branch(1.0)
    sm, vm = branch(-1.0)
    return jnp.concatenate([sp, sm], axis=0), jnp.concatenate([vp, vm], axis=0)


def fit_orientation_ref(
    euler: jnp.ndarray,
    gvec: jnp.ndarray,
    gmask: jnp.ndarray,
    obs: jnp.ndarray,
    obs_mask: jnp.ndarray,
    cfg: geometry.Config = geometry.DEFAULT_CONFIG,
) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """vmap-over-candidates oracle for kernels.fit_orientation."""

    def one(e):
        spot, valid = _spots_single(e, gvec, gmask, cfg)
        diff = spot[:, None, :] - obs[None, :, :]  # (P, O, 3)
        d2 = jnp.sum(diff * diff, axis=-1)
        d2 = jnp.where(obs_mask[None, :] > 0.5, d2, jnp.inf)
        dmin = jnp.min(d2, axis=1)
        hit = ((dmin <= cfg.match_tol**2) & (valid > 0.5)).astype(jnp.float32)
        matched = jnp.sum(hit)
        simulated = jnp.sum(valid)
        return matched / jnp.maximum(simulated, 1.0), matched, simulated

    return jax.vmap(one)(euler)


def log_filter_ref(img: jnp.ndarray, cfg: geometry.Config) -> jnp.ndarray:
    """Direct jnp LoG convolution, SAME padding, independent of lax.conv."""
    k = jnp.asarray(geometry.log_kernel_2d(cfg.log_sigma, cfg.log_half))
    half = cfg.log_half
    pad = jnp.pad(img, half, mode="constant")
    out = jnp.zeros_like(img)
    n = 2 * half + 1
    h, w = img.shape
    for dy in range(n):
        for dx in range(n):
            out = out + k[dy, dx] * pad[dy : dy + h, dx : dx + w]
    return out
