"""Pallas kernel: batched orientation scoring (the FitOrientation hot loop).

The paper's stage-2 analysis spends ~22M core-hours/week running
FitOrientation (Fig 8): for every grid point, an NLopt optimiser
searches orientation space, and each objective evaluation forward-
simulates diffraction spots and scores them against the observations.
That scalar C-per-task structure is the CPU/many-task design; the TPU
adaptation (DESIGN.md SHardware-Adaptation) batches B candidate
orientations per call and turns the per-candidate work into
MXU-shaped matmuls:

  1. Euler (B, 3) -> rotation matrices R (B, 3, 3)            [VPU]
  2. g = R @ G^T for G (S, 3)                                  [MXU: (B*3,3)x(3,S)]
  3. closed-form Friedel-pair omega solutions + detector
     projection -> predicted spots (B, 2S, 3)                  [VPU]
  4. pairwise squared distances to observed spots (O, 3) via
     |s|^2 - 2 s.o + |o|^2                                     [MXU: (B*2S,3)x(3,O)]
  5. min over O, tolerance count -> score (B,)                 [VPU]

Grid: one program per block of B_TILE candidates; G and the observation
list are broadcast to every program (index_map -> block 0).

VMEM per tile (f32, B_TILE=64, S=48, O=512):
  spots (64, 96, 3) + dist (64*96, 512) = 12.6 MiB for the distance
  tile - the dominant term. On real hardware O would be split into
  256-column panels (two passes, running min), halving footprint;
  interpret mode keeps the single-panel form for clarity. Documented
  in DESIGN.md SPerf.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .. import geometry

B_TILE = 64


def _rotmat(phi1, capphi, phi2):
    """Bunge ZXZ Euler angles (B,) each -> rotation matrices (B, 3, 3)."""
    c1, s1 = jnp.cos(phi1), jnp.sin(phi1)
    cp, sp = jnp.cos(capphi), jnp.sin(capphi)
    c2, s2 = jnp.cos(phi2), jnp.sin(phi2)
    r00 = c1 * c2 - s1 * cp * s2
    r01 = -c1 * s2 - s1 * cp * c2
    r02 = s1 * sp
    r10 = s1 * c2 + c1 * cp * s2
    r11 = -s1 * s2 + c1 * cp * c2
    r12 = -c1 * sp
    r20 = sp * s2
    r21 = sp * c2
    r22 = cp
    rows = [
        jnp.stack([r00, r01, r02], axis=-1),
        jnp.stack([r10, r11, r12], axis=-1),
        jnp.stack([r20, r21, r22], axis=-1),
    ]
    return jnp.stack(rows, axis=-2)  # (B, 3, 3)


def predicted_spots(euler, gvec, gmask, cfg: geometry.Config):
    """Forward-simulate spots for a batch of orientations.

    Args:
      euler: (B, 3) Euler angles, radians.
      gvec: (S, 3) reciprocal-lattice vectors.
      gmask: (S,) 1.0 for real rows, 0.0 for padding.

    Returns:
      spots: (B, 2S, 3) weighted coords (u_px, v_px, omega_deg * w).
      valid: (B, 2S) 1.0 where a spot exists and lands on the panel.

    Shared between the kernel body and the jnp reference so that the
    oracle check in tests is an independent *path*, not a copy (ref.py
    recomputes everything from scalars with vmap).
    """
    lam = cfg.wavelength
    k_in = cfg.k_in
    four_pi = 4.0 * math.pi

    rot = _rotmat(euler[:, 0], euler[:, 1], euler[:, 2])  # (B,3,3)
    b = euler.shape[0]
    s = gvec.shape[0]
    # g = R @ G^T : contract (B*3, 3) x (3, S) on the MXU.
    g = jnp.dot(
        rot.reshape(b * 3, 3), gvec.T, preferred_element_type=jnp.float32
    ).reshape(b, 3, s)
    gx, gy, gz = g[:, 0, :], g[:, 1, :], g[:, 2, :]  # (B, S)

    gsq = gx * gx + gy * gy + gz * gz
    a = jnp.sqrt(gx * gx + gy * gy)
    safe_a = jnp.maximum(a, 1e-12)
    t = -lam * gsq / four_pi / safe_a
    reachable = (jnp.abs(t) <= 1.0) & (a > 1e-8) & (gmask[None, :] > 0.5)
    tt = jnp.clip(t, -1.0, 1.0)
    phi = jnp.arctan2(gy, gx)
    acos_t = jnp.arccos(tt)

    spots = []
    valids = []
    for sign in (1.0, -1.0):
        omega = sign * acos_t - phi
        omega = jnp.mod(omega + math.pi, 2.0 * math.pi) - math.pi
        co, so = jnp.cos(omega), jnp.sin(omega)
        gxr = gx * co - gy * so
        gyr = gx * so + gy * co
        kfx = k_in + gxr
        kfy = gyr
        kfz = gz
        fwd = kfx > 0.0
        safe_kfx = jnp.where(fwd, kfx, 1.0)
        u = cfg.det_dist * kfy / safe_kfx / cfg.pixel_size + cfg.center
        v = cfg.det_dist * kfz / safe_kfx / cfg.pixel_size + cfg.center
        on_panel = (u >= 0.0) & (u < cfg.frame) & (v >= 0.0) & (v < cfg.frame)
        ok = reachable & fwd & on_panel
        w = jnp.degrees(omega) * cfg.omega_weight
        spots.append(jnp.stack([u, v, w], axis=-1))  # (B, S, 3)
        valids.append(ok)
    spot = jnp.concatenate(spots, axis=1)  # (B, 2S, 3)
    valid = jnp.concatenate(valids, axis=1).astype(jnp.float32)  # (B, 2S)
    # Park invalid spots far off-panel so they can never match anything.
    spot = jnp.where(valid[..., None] > 0.5, spot, -1.0e6)
    return spot, valid


#: Observation-axis panel width: the distance matrix is materialised
#: one (B*P, O_PANEL) panel at a time with a running minimum, instead
#: of the full (B*P, O) block. Arithmetic intensity of the distance
#: stage is ~1.4 FLOP/B (bandwidth-bound), so shrinking the resident
#: intermediate is the lever: 4x less traffic at O=512. Measured -46%
#: on the CPU PJRT path; on TPU it is what keeps the panel in VMEM
#: (EXPERIMENTS.md SPerf iteration, DESIGN.md SPerf).
O_PANEL = 128


def _score_block(spot, valid, obs, obs_mask, cfg: geometry.Config):
    """Match predicted spots against observations; completeness per cand.

    spot (B, P, 3), valid (B, P), obs (O, 3), obs_mask (O,).
    Returns (score (B,), matched (B,), simulated (B,)).
    """
    b, p, _ = spot.shape
    o = obs.shape[0]
    flat = spot.reshape(b * p, 3)
    s2 = jnp.sum(flat * flat, axis=1, keepdims=True)
    dmin = jnp.full((b * p,), jnp.inf, dtype=jnp.float32)
    panel = O_PANEL if o % O_PANEL == 0 else o
    for start in range(0, o, panel):
        # Fold the validity mask into the geometry: invalid rows are
        # displaced 1e7 px away, so they can never win the min — this
        # removes a full (B*P, O_PANEL) where/select pass per panel.
        ob = obs[start : start + panel]
        om = obs_mask[start : start + panel]
        ob = ob + (1.0 - om)[:, None] * 1.0e7
        # |s - o|^2 = |s|^2 - 2 s.o + |o|^2 ; cross term on the MXU.
        cross = jnp.dot(flat, ob.T, preferred_element_type=jnp.float32)
        d2 = s2 - 2.0 * cross + jnp.sum(ob * ob, axis=1)[None, :]
        dmin = jnp.minimum(dmin, jnp.min(d2, axis=1))
    dmin = dmin.reshape(b, p)
    tol2 = cfg.match_tol * cfg.match_tol
    hit = jnp.where((dmin <= tol2) & (valid > 0.5), 1.0, 0.0)
    matched = jnp.sum(hit, axis=1)
    simulated = jnp.sum(valid, axis=1)
    score = matched / jnp.maximum(simulated, 1.0)
    return score, matched, simulated


def _kernel(euler_ref, gvec_ref, gmask_ref, obs_ref, omask_ref,
            score_ref, matched_ref, simulated_ref, *, cfg: geometry.Config):
    spot, valid = predicted_spots(
        euler_ref[...], gvec_ref[...], gmask_ref[...], cfg
    )
    score, matched, simulated = _score_block(
        spot, valid, obs_ref[...], omask_ref[...], cfg
    )
    score_ref[...] = score
    matched_ref[...] = matched
    simulated_ref[...] = simulated


@functools.partial(jax.jit, static_argnames=("cfg",))
def fit_orientation(
    euler: jnp.ndarray,
    gvec: jnp.ndarray,
    gmask: jnp.ndarray,
    obs: jnp.ndarray,
    obs_mask: jnp.ndarray,
    cfg: geometry.Config = geometry.DEFAULT_CONFIG,
) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Score a batch of candidate orientations against observed spots.

    Args:
      euler: (B, 3) candidate Bunge Euler angles, radians. B % B_TILE == 0.
      gvec: (S, 3) reciprocal-lattice vectors (geometry.gvectors).
      gmask: (S,) validity mask for padded G rows.
      obs: (O, 3) observed spots in weighted coords
        (u_px, v_px, omega_deg * cfg.omega_weight).
      obs_mask: (O,) 1.0 for real observations.

    Returns:
      score: (B,) completeness in [0, 1] - fraction of simulated spots
        matched within cfg.match_tol (the paper's "confidence").
      matched: (B,) matched spot counts.
      simulated: (B,) simulated (reachable, on-panel) spot counts.
    """
    b = euler.shape[0]
    if b % B_TILE:
        raise ValueError(f"batch {b} must be a multiple of {B_TILE}")
    s = gvec.shape[0]
    o = obs.shape[0]
    grid = (b // B_TILE,)
    vec = jax.ShapeDtypeStruct((b,), jnp.float32)
    vspec = pl.BlockSpec((B_TILE,), lambda i: (i,))
    return pl.pallas_call(
        functools.partial(_kernel, cfg=cfg),
        grid=grid,
        in_specs=[
            pl.BlockSpec((B_TILE, 3), lambda i: (i, 0)),
            pl.BlockSpec((s, 3), lambda i: (0, 0)),
            pl.BlockSpec((s,), lambda i: (0,)),
            pl.BlockSpec((o, 3), lambda i: (0, 0)),
            pl.BlockSpec((o,), lambda i: (0,)),
        ],
        out_specs=[vspec, vspec, vspec],
        out_shape=[vec, vec, vec],
        interpret=True,
    )(euler, gvec, gmask, obs, obs_mask)
