"""Pallas kernel: fused dark-subtract + 3x3 median filter + binarize.

The NF-HEDM stage-1 reduction (paper SVI-A) runs, per frame: a median
over the dark stack (done once, see model.dark_median), a 3x3 median
filter, a Laplacian-of-Gaussian filter, and a threshold. The per-pixel
median filter is the byte-hottest step (9 reads/pixel over an 8 MB
frame); this kernel fuses dark subtraction, the median, and the
intensity threshold into one VMEM-resident pass.

Layout strategy (the TPU adaptation, DESIGN.md SHardware-Adaptation):
instead of halo exchange between tiles, the L2 model materialises the
nine shifted copies of the (padded) frame as a (9, H, W) stack - XLA
fuses the slices into the pad, so no extra HBM traffic materialises -
and the kernel reduces over the leading axis with a 19-op min/max
median network, fully vectorised on the VPU. Tiles are (TILE_H, TILE_W)
blocks of the frame; the stack tile is (9, TILE_H, TILE_W).

VMEM footprint per tile (f32): (9 + 1 + 1 + 1) * TILE_H * TILE_W * 4
= 12 * 128 * 256 * 4 = 1.5 MiB, comfortably inside the ~16 MiB VMEM of
a TPU core with room for double-buffering.

interpret=True everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls, so the kernel lowers to plain HLO (see aot recipe).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TILE_H = 128
TILE_W = 256

# Median-of-9 exchange network (Paeth). Each pair (i, j) replaces
# (p[i], p[j]) with (min, max); after the 19 exchanges p[4] is the median.
_MEDIAN9_NETWORK = (
    (1, 2), (4, 5), (7, 8), (0, 1), (3, 4), (6, 7), (1, 2), (4, 5),
    (7, 8), (0, 3), (5, 8), (4, 7), (3, 6), (1, 4), (2, 5), (4, 7),
    (4, 2), (6, 4), (4, 2),
)


def median9(planes: list[jnp.ndarray]) -> jnp.ndarray:
    """Vectorised median of nine equally-shaped arrays."""
    p = list(planes)
    for i, j in _MEDIAN9_NETWORK:
        lo = jnp.minimum(p[i], p[j])
        hi = jnp.maximum(p[i], p[j])
        p[i], p[j] = lo, hi
    return p[4]


def _kernel(stack_ref, dark_ref, med_ref, mask_ref, *, threshold: float):
    """One (TILE_H, TILE_W) tile: median9(stack) - dark, thresholded.

    stack_ref: (9, TILE_H, TILE_W) shifted copies of the raw frame.
    dark_ref:  (TILE_H, TILE_W) per-pixel dark median.
    med_ref:   output, dark-subtracted median (clamped at 0).
    mask_ref:  output, 1.0 where the subtracted median exceeds threshold.
    """
    planes = [stack_ref[i] for i in range(9)]
    med = median9(planes)
    sub = jnp.maximum(med - dark_ref[...], 0.0)
    med_ref[...] = sub
    mask_ref[...] = jnp.where(sub > threshold, 1.0, 0.0).astype(jnp.float32)


@functools.partial(jax.jit, static_argnames=("threshold",))
def median_threshold(
    stack: jnp.ndarray, dark: jnp.ndarray, *, threshold: float
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Fused 3x3-median + dark subtract + intensity threshold.

    Args:
      stack: (9, H, W) f32 - the nine 3x3-neighbourhood shifts of the
        frame (edge-clamped), produced by model.shift_stack.
      dark: (H, W) f32 dark-median frame.
      threshold: intensity threshold applied after subtraction.

    Returns:
      (median_sub, mask): both (H, W) f32; mask is {0.0, 1.0}.
    """
    _, h, w = stack.shape
    if h % TILE_H or w % TILE_W:
        raise ValueError(f"frame {h}x{w} must tile by {TILE_H}x{TILE_W}")
    grid = (h // TILE_H, w // TILE_W)
    out_shape = [
        jax.ShapeDtypeStruct((h, w), jnp.float32),
        jax.ShapeDtypeStruct((h, w), jnp.float32),
    ]
    spec2d = pl.BlockSpec((TILE_H, TILE_W), lambda i, j: (i, j))
    return tuple(
        pl.pallas_call(
            functools.partial(_kernel, threshold=threshold),
            grid=grid,
            in_specs=[
                pl.BlockSpec((9, TILE_H, TILE_W), lambda i, j: (0, i, j)),
                spec2d,
            ],
            out_specs=[spec2d, spec2d],
            out_shape=out_shape,
            interpret=True,
        )(stack, dark)
    )
