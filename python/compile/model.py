"""Layer 2: the HEDM compute graphs, AOT-lowered for the Rust runtime.

Each public function here is a jit-able JAX computation with *static*
shapes fixed by geometry.Config; aot.py lowers them to HLO text and the
Rust runtime (rust/src/runtime) executes them from leaf tasks of the
dataflow engine. The functions call the L1 Pallas kernels for their
hot loops and plain jnp/lax for glue.

Entry points (shapes for the default config, frame=512):

  dark_median   (K, H, W)                     -> (H, W)
  reduce_frame  (9, H, W), (H, W)             -> (H, W) sub, (H, W) mask,
                                                 (H, W) log response, (1,) count
  peak_search   (H, W) mask, (H, W) intensity -> (H, W) peaks, (H, W) weighted
  fit_orientation (B,3), (S,3), (S,), (O,3), (O,) -> (B,), (B,), (B,)

`shift_stack` is traced *inside* reduce_frame's artifact so the Rust
side feeds the raw frame directly; the 9-plane stack never crosses the
FFI boundary.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import geometry
from .kernels import fit_orientation as fit_kernel
from .kernels import median as median_kernel


def shift_stack(frame: jnp.ndarray) -> jnp.ndarray:
    """(H, W) -> (9, H, W): the 3x3 neighbourhood shifts, edge-clamped.

    Plane order is row-major over (dy, dx) in {-1,0,1}^2; plane 4 is the
    identity. XLA fuses these slices of the padded frame, so this is
    layout glue, not a data copy at HBM scale.
    """
    padded = jnp.pad(frame, 1, mode="edge")
    h, w = frame.shape
    planes = [
        padded[dy : dy + h, dx : dx + w]
        for dy in range(3)
        for dx in range(3)
    ]
    return jnp.stack(planes, axis=0)


def dark_median(stack: jnp.ndarray) -> jnp.ndarray:
    """Median over the dark-frame stack (K, H, W) -> (H, W).

    The paper's stage-1 'median calculation on each pixel of the
    detector, using all images' (SVI-A). Sort-based; K is small (8).
    """
    return jnp.median(stack, axis=0).astype(jnp.float32)


def log_filter(img: jnp.ndarray, cfg: geometry.Config) -> jnp.ndarray:
    """Laplacian-of-Gaussian response, SAME (zero) padding.

    Expressed as 25 shifted-and-scaled adds rather than `lax.conv`: the
    `convolution` HLO op mis-executes (returns zeros) on the pinned
    xla_extension 0.5.1 CPU runtime the Rust side links, while slices
    and adds round-trip fine — and XLA fuses this into one loop anyway.
    """
    k = geometry.log_kernel_2d(cfg.log_sigma, cfg.log_half)
    half = cfg.log_half
    h, w = img.shape
    padded = jnp.pad(img, half, mode="constant")
    out = jnp.zeros_like(img)
    n = 2 * half + 1
    for dy in range(n):
        for dx in range(n):
            out = out + float(k[dy, dx]) * jax.lax.dynamic_slice(
                padded, (dy, dx), (h, w)
            )
    return out


@functools.partial(jax.jit, static_argnames=("cfg",))
def reduce_frame(
    frame: jnp.ndarray,
    dark: jnp.ndarray,
    cfg: geometry.Config = geometry.DEFAULT_CONFIG,
):
    """NF/FF stage-1 per-frame reduction (SVI-A).

    median filter (Pallas) -> dark subtract -> LoG edge/blob filter ->
    joint threshold -> binary diffraction-signal mask.

    Returns (sub, mask, logresp, count):
      sub: dark-subtracted median-filtered frame (H, W).
      mask: binary signal mask (H, W) - the '~1 MB binary file' content.
      logresp: LoG response (H, W) (kept for peak characterisation).
      count: (1,) number of signal pixels (sparsity telemetry).
    """
    stack = shift_stack(frame)
    sub, intensity_mask = median_kernel.median_threshold(
        stack, dark, threshold=cfg.intensity_threshold
    )
    logresp = log_filter(sub, cfg)
    mask = intensity_mask * jnp.where(logresp > cfg.log_threshold, 1.0, 0.0)
    count = jnp.sum(mask, dtype=jnp.float32).reshape(1)
    return sub, mask, logresp, count


@functools.partial(jax.jit, static_argnames=("cfg",))
def peak_search(
    mask: jnp.ndarray,
    intensity: jnp.ndarray,
    cfg: geometry.Config = geometry.DEFAULT_CONFIG,
):
    """FF stage-1 peak characterisation support (SVI-C).

    Marks local maxima of `intensity` within masked regions (5x5
    window) and emits the masked intensity; the Rust side walks the
    maxima to produce the ~50 KB text file of peak properties
    (centroids via connected components in rust/src/hedm/ccl.rs).

    Returns (peaks, weighted): both (H, W) f32.
    """
    masked = mask * intensity
    # 5x5 windowed max as 25 shifted maxima (see log_filter for why
    # reduce_window/conv are avoided in AOT artifacts).
    h, w = masked.shape
    pad = 2
    padded = jnp.pad(masked, pad, mode="constant", constant_values=-jnp.inf)
    neigh = jnp.full_like(masked, -jnp.inf)
    for dy in range(5):
        for dx in range(5):
            neigh = jnp.maximum(
                neigh, jax.lax.dynamic_slice(padded, (dy, dx), (h, w))
            )
    peaks = jnp.where((masked >= neigh) & (mask > 0.5), 1.0, 0.0)
    return peaks.astype(jnp.float32), masked


def fit_orientation(
    euler: jnp.ndarray,
    gvec: jnp.ndarray,
    gmask: jnp.ndarray,
    obs: jnp.ndarray,
    obs_mask: jnp.ndarray,
    cfg: geometry.Config = geometry.DEFAULT_CONFIG,
):
    """Stage-2 batched orientation scoring; see kernels.fit_orientation."""
    return fit_kernel.fit_orientation(euler, gvec, gmask, obs, obs_mask, cfg)
