"""AOT lowering: artifacts exist, manifest is consistent, HLO is text."""

import json
import pathlib

import pytest

from compile import aot, geometry


@pytest.fixture(scope="module")
def built(tmp_path_factory):
    """Build a small-frame artifact set once for the module."""
    out = tmp_path_factory.mktemp("artifacts")
    cfg = geometry.Config(frame=256, det_dist=1.25e5)
    manifest = aot.build(out, cfg)
    return out, cfg, manifest


class TestArtifacts:
    def test_all_entry_points_emitted(self, built):
        out, cfg, manifest = built
        for name in aot.entry_points(cfg):
            assert (out / f"{name}.hlo.txt").exists(), name
            assert name in manifest["entry_points"]

    def test_hlo_is_text(self, built):
        out, cfg, _ = built
        text = (out / "fit_orientation.hlo.txt").read_text()
        assert text.startswith("HloModule")
        assert "ENTRY" in text

    def test_manifest_shapes(self, built):
        _, cfg, manifest = built
        fit = manifest["entry_points"]["fit_orientation"]
        assert fit["inputs"][0]["shape"] == [cfg.b_batch, 3]
        assert fit["inputs"][3]["shape"] == [cfg.o_max, 3]
        assert [o["shape"] for o in fit["outputs"]] == [[cfg.b_batch]] * 3
        red = manifest["entry_points"]["reduce_frame"]
        assert red["inputs"][0]["shape"] == [cfg.frame, cfg.frame]
        assert len(red["outputs"]) == 4

    def test_manifest_gvectors(self, built):
        _, cfg, manifest = built
        assert len(manifest["gvectors"]) == cfg.s_max
        assert len(manifest["gvector_mask"]) == cfg.s_max

    def test_manifest_config_round_trips(self, built):
        out, cfg, _ = built
        data = json.loads((out / "manifest.json").read_text())
        assert data["config"]["frame"] == cfg.frame
        assert data["config"]["wavelength"] == pytest.approx(cfg.wavelength)

    def test_deterministic_sha(self, built, tmp_path):
        """Lowering is deterministic: same config -> same artifact hash."""
        out, cfg, manifest = built
        again = aot.build(tmp_path, cfg)
        for name, entry in manifest["entry_points"].items():
            assert again["entry_points"][name]["sha256"] == entry["sha256"], name

    def test_no_custom_calls(self, built):
        """interpret=True must leave no Mosaic custom-calls in the HLO
        (the CPU PJRT plugin cannot execute them)."""
        out, _, _ = built
        for path in out.glob("*.hlo.txt"):
            assert "custom-call" not in path.read_text(), path.name
