"""L2 model graphs: reduction pipeline, dark median, peak search."""

import numpy as np
import pytest
import jax.numpy as jnp

from compile import geometry, model
from compile.kernels import ref


def splat_gaussian(frame: np.ndarray, u: float, v: float, amp: float, sigma: float = 1.5):
    """Add a Gaussian diffraction spot at (u, v) [pixels]; mirrors the
    Rust detector simulator's splatting."""
    h, w = frame.shape
    r = int(3 * sigma) + 1
    cu, cv = int(round(u)), int(round(v))
    for y in range(max(0, cv - r), min(h, cv + r + 1)):
        for x in range(max(0, cu - r), min(w, cu + r + 1)):
            d2 = (y - v) ** 2 + (x - u) ** 2
            frame[y, x] += amp * np.exp(-d2 / (2 * sigma * sigma))


class TestDarkMedian:
    def test_median_of_constant_stack(self):
        stack = jnp.full((8, 64, 64), 13.0)
        out = model.dark_median(stack)
        np.testing.assert_allclose(out, 13.0)

    def test_robust_to_outlier_frame(self, rng):
        stack = np.full((8, 32, 32), 50.0, np.float32)
        stack[3] = 5000.0  # one bad dark frame
        out = model.dark_median(jnp.asarray(stack))
        np.testing.assert_allclose(out, 50.0)

    def test_matches_numpy(self, rng):
        stack = rng.uniform(0, 100, (8, 32, 32)).astype(np.float32)
        out = model.dark_median(jnp.asarray(stack))
        np.testing.assert_allclose(out, np.median(stack, axis=0), atol=1e-5)


class TestLogFilter:
    def test_matches_direct_convolution(self, cfg, rng):
        img = jnp.asarray(rng.normal(size=(64, 64)).astype(np.float32))
        got = model.log_filter(img, cfg)
        want = ref.log_filter_ref(img, cfg)
        np.testing.assert_allclose(got, want, atol=1e-3, rtol=1e-4)

    def test_flat_image_zero_response(self, cfg):
        img = jnp.full((64, 64), 100.0)
        out = np.asarray(model.log_filter(img, cfg))
        # Zero-mean kernel: interior response vanishes on flat input.
        assert np.abs(out[8:-8, 8:-8]).max() < 1e-2


class TestReduceFrame:
    """End-to-end stage-1 reduction on a synthetic frame."""

    def make_frame(self, cfg, rng, spots, amp=400.0):
        frame = rng.normal(40.0, 3.0, (cfg.frame, cfg.frame)).astype(np.float32)
        for u, v, _ in spots:
            splat_gaussian(frame, u, v, amp)
        dark = np.full((cfg.frame, cfg.frame), 40.0, np.float32)
        return frame, dark

    def test_detects_spots_and_rejects_background(self, cfg, rng):
        spots = geometry.simulate_spots((0.3, 0.7, 1.1), cfg)[:12]
        frame, dark = self.make_frame(cfg, rng, spots)
        sub, mask, logresp, count = model.reduce_frame(
            jnp.asarray(frame), jnp.asarray(dark), cfg
        )
        mask = np.asarray(mask)
        # every injected spot produces signal at its centre
        for u, v, _ in spots:
            assert mask[int(round(v)), int(round(u))] == 1.0, (u, v)
        # sparsity: the paper's 8 MB -> 1 MB reduction implies a sparse mask
        assert float(count[0]) == mask.sum()
        assert mask.mean() < 0.02

    def test_empty_frame_yields_empty_mask(self, cfg, rng):
        frame = rng.normal(40.0, 3.0, (cfg.frame, cfg.frame)).astype(np.float32)
        dark = np.full((cfg.frame, cfg.frame), 40.0, np.float32)
        _, mask, _, count = model.reduce_frame(jnp.asarray(frame), jnp.asarray(dark), cfg)
        assert float(count[0]) == 0.0

    def test_count_is_mask_sum(self, cfg, rng):
        spots = geometry.simulate_spots((1.9, 0.4, 0.8), cfg)[:6]
        frame, dark = self.make_frame(cfg, rng, spots)
        _, mask, _, count = model.reduce_frame(jnp.asarray(frame), jnp.asarray(dark), cfg)
        assert float(count[0]) == float(np.asarray(mask).sum())


class TestPeakSearch:
    def test_single_blob_single_peak(self, cfg):
        h = cfg.frame
        inten = np.zeros((h, h), np.float32)
        splat_gaussian(inten, 100.0, 120.0, 500.0)
        mask = (inten > 50).astype(np.float32)
        peaks, weighted = model.peak_search(jnp.asarray(mask), jnp.asarray(inten), cfg)
        peaks = np.asarray(peaks)
        ys, xs = np.nonzero(peaks)
        assert len(ys) == 1
        assert (ys[0], xs[0]) == (120, 100)

    def test_two_separated_blobs(self, cfg):
        h = cfg.frame
        inten = np.zeros((h, h), np.float32)
        splat_gaussian(inten, 50.0, 60.0, 500.0)
        splat_gaussian(inten, 150.0, 160.0, 300.0)
        mask = (inten > 50).astype(np.float32)
        peaks, _ = model.peak_search(jnp.asarray(mask), jnp.asarray(inten), cfg)
        assert int(np.asarray(peaks).sum()) == 2

    def test_no_mask_no_peaks(self, cfg, rng):
        h = cfg.frame
        inten = rng.uniform(0, 100, (h, h)).astype(np.float32)
        mask = np.zeros((h, h), np.float32)
        peaks, weighted = model.peak_search(jnp.asarray(mask), jnp.asarray(inten), cfg)
        assert float(np.asarray(peaks).sum()) == 0.0
        assert float(np.asarray(weighted).sum()) == 0.0
