"""Geometry module: lattice, rotations, forward model invariants."""

import math

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from compile import geometry

ANGLE = st.floats(min_value=-math.pi, max_value=math.pi, allow_nan=False)


class TestFccSelection:
    def test_111_allowed(self):
        assert geometry.fcc_allowed(1, 1, 1)

    def test_200_allowed(self):
        assert geometry.fcc_allowed(2, 0, 0)

    def test_100_forbidden(self):
        assert not geometry.fcc_allowed(1, 0, 0)

    def test_210_forbidden(self):
        assert not geometry.fcc_allowed(2, 1, 0)

    def test_negative_indices(self):
        assert geometry.fcc_allowed(-1, 1, -1)
        assert not geometry.fcc_allowed(-1, 0, 0)


class TestGvectors:
    def test_shape_and_pad(self, cfg):
        g = geometry.gvectors(cfg)
        assert g.shape == (cfg.s_max, 3)
        assert g.dtype == np.float32

    def test_sorted_by_norm(self, cfg):
        g = geometry.gvectors(cfg)
        m = geometry.gvector_mask(cfg) > 0.5
        norms = np.linalg.norm(g[m], axis=1)
        assert np.all(np.diff(norms) >= -1e-4)

    def test_smallest_is_111(self, cfg):
        g = geometry.gvectors(cfg)
        scale = 2 * math.pi / cfg.lattice_a
        assert np.isclose(np.linalg.norm(g[0]), scale * math.sqrt(3), rtol=1e-5)

    def test_all_fcc_allowed(self, cfg):
        g = geometry.gvectors(cfg)
        m = geometry.gvector_mask(cfg) > 0.5
        scale = 2 * math.pi / cfg.lattice_a
        hkl = np.round(g[m] / scale).astype(int)
        for h, k, l in hkl:
            assert geometry.fcc_allowed(h, k, l), (h, k, l)

    def test_inversion_symmetric(self, cfg):
        """Friedel: if G is in the set, so is -G (both FCC-allowed)."""
        g = geometry.gvectors(cfg)
        m = geometry.gvector_mask(cfg) > 0.5
        rows = {tuple(np.round(v, 4)) for v in g[m]}
        for v in g[m]:
            assert tuple(np.round(-v, 4)) in rows


class TestEuler:
    @given(phi1=ANGLE, capphi=ANGLE, phi2=ANGLE)
    @settings(max_examples=50, deadline=None)
    def test_rotation_is_orthonormal(self, phi1, capphi, phi2):
        r = geometry.euler_to_matrix(phi1, capphi, phi2)
        assert np.allclose(r @ r.T, np.eye(3), atol=1e-12)
        assert np.isclose(np.linalg.det(r), 1.0, atol=1e-12)

    def test_identity(self):
        assert np.allclose(geometry.euler_to_matrix(0, 0, 0), np.eye(3))

    def test_z_rotation_composition(self):
        """phi1 and phi2 both rotate about z when capphi=0."""
        r = geometry.euler_to_matrix(0.3, 0.0, 0.4)
        expected = geometry.euler_to_matrix(0.7, 0.0, 0.0)
        assert np.allclose(r, expected, atol=1e-12)


class TestForwardModel:
    def test_spots_on_panel(self, cfg):
        spots = geometry.simulate_spots((0.3, 0.7, 1.1), cfg)
        assert len(spots) > 0
        assert np.all(spots[:, 0] >= 0) and np.all(spots[:, 0] < cfg.frame)
        assert np.all(spots[:, 1] >= 0) and np.all(spots[:, 1] < cfg.frame)
        assert np.all(np.abs(spots[:, 2]) <= 180.0)

    @given(phi1=ANGLE, capphi=ANGLE, phi2=ANGLE)
    @settings(max_examples=20, deadline=None)
    def test_bragg_condition_holds(self, phi1, capphi, phi2):
        """Every emitted spot satisfies the elastic scattering condition.

        Re-derives |k_out| == |k_in| from the (u, v, omega) output alone,
        an end-to-end consistency check of the closed-form omega solve.
        """
        cfg = geometry.Config(frame=256, det_dist=1.25e5)
        spots = geometry.simulate_spots((phi1, capphi, phi2), cfg)
        for u, v, omega_deg in spots:
            # Reconstruct k_out direction from the detector position.
            y = (u - cfg.center) * cfg.pixel_size
            z = (v - cfg.center) * cfg.pixel_size
            x = cfg.det_dist
            norm = math.sqrt(x * x + y * y + z * z)
            k_out = cfg.k_in * np.array([x, y, z]) / norm
            k_in = np.array([cfg.k_in, 0.0, 0.0])
            g = k_out - k_in
            # Elastic: |k_out| = |k_in| by construction; check g is a
            # rotated lattice vector: |g| must match one of the |G|s.
            norms = np.linalg.norm(
                geometry.gvectors(cfg)[geometry.gvector_mask(cfg) > 0.5], axis=1
            )
            assert np.min(np.abs(norms - np.linalg.norm(g))) < 1e-3

    def test_friedel_pairs_present(self, cfg):
        """Most spots appear in +/- omega-solution pairs from the same G."""
        spots = geometry.simulate_spots((0.0, 0.0, 0.0), cfg)
        # Reference orientation is high symmetry: expect an even count.
        assert len(spots) % 2 == 0

    def test_rotating_orientation_moves_spots(self, cfg):
        a = geometry.simulate_spots((0.1, 0.2, 0.3), cfg)
        b = geometry.simulate_spots((0.4, 0.8, 1.2), cfg)
        assert a.shape != b.shape or not np.allclose(a, b)


class TestLogKernel:
    def test_zero_mean(self):
        k = geometry.log_kernel_2d()
        assert abs(float(k.sum())) < 1e-5

    def test_center_positive(self):
        """Negated-LoG convention: bright blob centre responds positively."""
        k = geometry.log_kernel_2d()
        assert k[geometry.LOG_HALF, geometry.LOG_HALF] > 0

    def test_shape(self):
        k = geometry.log_kernel_2d(sigma=1.0, half=3)
        assert k.shape == (7, 7)

    def test_detects_blob(self):
        """Convolving a Gaussian blob yields max response at its centre."""
        k = geometry.log_kernel_2d()
        img = np.zeros((32, 32), np.float32)
        y, x = np.mgrid[0:32, 0:32]
        img += 100 * np.exp(-((y - 16.0) ** 2 + (x - 16.0) ** 2) / 4.0)
        from scipy.signal import convolve2d

        resp = convolve2d(img, k, mode="same")
        assert np.unravel_index(resp.argmax(), resp.shape) == (16, 16)
