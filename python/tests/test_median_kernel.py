"""Pallas median/threshold kernel vs the sort-based oracle."""

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st
import jax.numpy as jnp

from compile import model
from compile.kernels import median, ref


def run_both(frame, dark, threshold):
    stack = model.shift_stack(jnp.asarray(frame))
    got = median.median_threshold(stack, jnp.asarray(dark), threshold=threshold)
    want = ref.median_threshold_ref(stack, jnp.asarray(dark), threshold=threshold)
    return got, want


class TestMedianNetwork:
    """The 19-op exchange network against jnp.median directly."""

    @given(seed=st.integers(0, 2**31 - 1))
    @settings(max_examples=30, deadline=None)
    def test_network_matches_sort(self, seed):
        rng = np.random.default_rng(seed)
        planes = [jnp.asarray(rng.normal(size=(8, 16)).astype(np.float32))
                  for _ in range(9)]
        got = median.median9(planes)
        want = jnp.median(jnp.stack(planes), axis=0)
        np.testing.assert_allclose(got, want, rtol=0, atol=0)

    def test_network_with_duplicates(self):
        planes = [jnp.full((4, 4), float(v)) for v in [3, 1, 3, 1, 3, 1, 3, 1, 3]]
        assert float(median.median9(planes)[0, 0]) == 3.0

    def test_network_all_equal(self):
        planes = [jnp.full((4, 4), 7.0)] * 9
        assert float(median.median9(planes)[0, 0]) == 7.0


class TestKernelVsRef:
    def test_random_frame(self, rng):
        frame = rng.uniform(0, 400, (256, 256)).astype(np.float32)
        dark = rng.uniform(0, 60, (256, 256)).astype(np.float32)
        (sub, mask), (sub_r, mask_r) = run_both(frame, dark, 80.0)
        np.testing.assert_allclose(sub, sub_r, atol=0)
        np.testing.assert_array_equal(np.asarray(mask), np.asarray(mask_r))

    def test_all_below_threshold(self, rng):
        frame = rng.uniform(0, 10, (128, 256)).astype(np.float32)
        dark = np.zeros((128, 256), np.float32)
        (sub, mask), _ = run_both(frame, dark, 80.0)
        assert float(jnp.sum(mask)) == 0.0

    def test_all_above_threshold(self):
        frame = np.full((128, 256), 500.0, np.float32)
        dark = np.zeros((128, 256), np.float32)
        (sub, mask), _ = run_both(frame, dark, 80.0)
        assert float(jnp.sum(mask)) == 128 * 256
        np.testing.assert_allclose(sub, 500.0)

    def test_dark_subtraction_clamps_at_zero(self):
        frame = np.full((128, 256), 10.0, np.float32)
        dark = np.full((128, 256), 50.0, np.float32)
        (sub, mask), _ = run_both(frame, dark, 5.0)
        assert float(jnp.min(sub)) == 0.0
        assert float(jnp.sum(mask)) == 0.0

    def test_salt_noise_removed(self, rng):
        """The defining property of a median filter: isolated hot pixels
        (detector 'zingers') vanish; a 3x3 solid blob survives."""
        frame = np.zeros((128, 256), np.float32)
        frame[40, 40] = 1000.0  # isolated zinger
        frame[80:83, 80:83] = 1000.0  # real 3x3 signal blob
        dark = np.zeros_like(frame)
        (sub, mask), _ = run_both(frame, dark, 80.0)
        assert float(mask[40, 40]) == 0.0
        assert float(mask[81, 81]) == 1.0

    @given(
        seed=st.integers(0, 2**31 - 1),
        h_tiles=st.integers(1, 2),
        w_tiles=st.integers(1, 2),
        threshold=st.floats(0.0, 200.0),
    )
    @settings(max_examples=15, deadline=None)
    def test_property_sweep(self, seed, h_tiles, w_tiles, threshold):
        """Hypothesis sweep over tile-multiple shapes and thresholds."""
        rng = np.random.default_rng(seed)
        h, w = median.TILE_H * h_tiles, median.TILE_W * w_tiles
        frame = rng.uniform(0, 300, (h, w)).astype(np.float32)
        dark = rng.uniform(0, 40, (h, w)).astype(np.float32)
        (sub, mask), (sub_r, mask_r) = run_both(frame, dark, threshold)
        np.testing.assert_allclose(sub, sub_r, atol=0)
        np.testing.assert_array_equal(np.asarray(mask), np.asarray(mask_r))

    def test_rejects_untileable_shape(self):
        frame = jnp.zeros((100, 100))
        dark = jnp.zeros((100, 100))
        stack = model.shift_stack(frame)
        with pytest.raises(ValueError, match="must tile"):
            median.median_threshold(stack, dark, threshold=1.0)


class TestShiftStack:
    def test_center_plane_is_identity(self, rng):
        frame = jnp.asarray(rng.normal(size=(16, 16)).astype(np.float32))
        stack = model.shift_stack(frame)
        np.testing.assert_array_equal(np.asarray(stack[4]), np.asarray(frame))

    def test_plane_order(self):
        frame = jnp.asarray(np.arange(16, dtype=np.float32).reshape(4, 4))
        stack = model.shift_stack(frame)
        # plane 0 is the (dy=-1, dx=-1) shift: stack[0][i,j] = frame[i-1,j-1]
        assert float(stack[0][1, 1]) == float(frame[0, 0])
        # plane 8 is (dy=+1, dx=+1): stack[8][i,j] = frame[i+1,j+1]
        assert float(stack[8][1, 1]) == float(frame[2, 2])

    def test_edges_clamped(self):
        frame = jnp.asarray(np.arange(16, dtype=np.float32).reshape(4, 4))
        stack = model.shift_stack(frame)
        assert float(stack[0][0, 0]) == float(frame[0, 0])
