"""Shared fixtures for the python test suite."""

import numpy as np
import pytest

from compile import geometry


@pytest.fixture(scope="session")
def cfg():
    """Small-frame config so interpret-mode Pallas stays fast in CI."""
    return geometry.Config(frame=256, det_dist=1.25e5)


@pytest.fixture(scope="session")
def gvecs(cfg):
    return geometry.gvectors(cfg), geometry.gvector_mask(cfg)


@pytest.fixture()
def rng():
    return np.random.default_rng(1234)


def make_obs(spots: np.ndarray, cfg: geometry.Config) -> tuple[np.ndarray, np.ndarray]:
    """Pack an (n,3) spot list into padded (O,3)/(O,) kernel inputs."""
    obs = np.full((cfg.o_max, 3), -1.0e6, dtype=np.float32)
    mask = np.zeros((cfg.o_max,), dtype=np.float32)
    n = min(len(spots), cfg.o_max)
    if n:
        obs[:n, 0] = spots[:n, 0]
        obs[:n, 1] = spots[:n, 1]
        obs[:n, 2] = spots[:n, 2] * cfg.omega_weight
        mask[:n] = 1.0
    return obs, mask
