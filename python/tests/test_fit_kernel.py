"""Pallas fit_orientation kernel vs the vmap oracle + physics properties."""

import math

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st
import jax.numpy as jnp

from compile import geometry
from compile.kernels import fit_orientation as fk
from compile.kernels import ref

from .conftest import make_obs

ANGLE = st.floats(min_value=0.0, max_value=2 * math.pi, allow_nan=False)


def run_kernel(euler, obs, omask, cfg, g, gm):
    return fk.fit_orientation(
        jnp.asarray(euler), jnp.asarray(g), jnp.asarray(gm),
        jnp.asarray(obs), jnp.asarray(omask), cfg,
    )


def run_ref(euler, obs, omask, cfg, g, gm):
    return ref.fit_orientation_ref(
        jnp.asarray(euler), jnp.asarray(g), jnp.asarray(gm),
        jnp.asarray(obs), jnp.asarray(omask), cfg,
    )


class TestKernelVsRef:
    def test_random_batch(self, cfg, gvecs, rng):
        g, gm = gvecs
        spots = geometry.simulate_spots((0.3, 0.7, 1.1), cfg)
        obs, omask = make_obs(spots, cfg)
        euler = rng.uniform(0, 2 * np.pi, (128, 3)).astype(np.float32)
        got = run_kernel(euler, obs, omask, cfg, g, gm)
        want = run_ref(euler, obs, omask, cfg, g, gm)
        for a, b in zip(got, want):
            np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-5)

    def test_empty_observations(self, cfg, gvecs, rng):
        g, gm = gvecs
        obs, omask = make_obs(np.zeros((0, 3)), cfg)
        euler = rng.uniform(0, 2 * np.pi, (64, 3)).astype(np.float32)
        score, matched, sim = run_kernel(euler, obs, omask, cfg, g, gm)
        assert float(jnp.max(score)) == 0.0
        assert float(jnp.max(matched)) == 0.0
        assert float(jnp.min(sim)) >= 0.0

    @given(seed=st.integers(0, 2**31 - 1), p1=ANGLE, pp=ANGLE, p2=ANGLE)
    @settings(max_examples=10, deadline=None)
    def test_property_sweep(self, seed, p1, pp, p2):
        """Arbitrary ground-truth grain; kernel == oracle everywhere."""
        cfg = geometry.Config(frame=256, det_dist=1.25e5)
        g = geometry.gvectors(cfg)
        gm = geometry.gvector_mask(cfg)
        rng = np.random.default_rng(seed)
        spots = geometry.simulate_spots((p1, pp, p2), cfg)
        obs, omask = make_obs(spots, cfg)
        euler = rng.uniform(0, 2 * np.pi, (64, 3)).astype(np.float32)
        euler[0] = [p1, pp, p2]
        got = run_kernel(euler, obs, omask, cfg, g, gm)
        want = run_ref(euler, obs, omask, cfg, g, gm)
        # The kernel computes |s|^2 - 2 s.o + |o|^2 (MXU form); the ref
        # computes (s-o)^2 directly. A spot sitting *exactly* on the
        # match-tolerance sphere can land on opposite sides under the
        # two roundings, so allow a one-spot disagreement per candidate.
        sim = np.asarray(want[2])
        np.testing.assert_allclose(got[2], sim, atol=0)  # simulated: exact
        matched_diff = np.abs(np.asarray(got[1]) - np.asarray(want[1]))
        assert matched_diff.max() <= 1, f"matched counts differ by {matched_diff.max()}"
        score_tol = 1.0 / np.maximum(sim, 1.0) + 1e-5
        assert np.all(np.abs(np.asarray(got[0]) - np.asarray(want[0])) <= score_tol)

    def test_rejects_bad_batch(self, cfg, gvecs):
        g, gm = gvecs
        obs, omask = make_obs(np.zeros((0, 3)), cfg)
        with pytest.raises(ValueError, match="multiple"):
            run_kernel(np.zeros((37, 3), np.float32), obs, omask, cfg, g, gm)


class TestRecovery:
    """The scientific invariant: the true orientation wins the scan."""

    def test_true_orientation_scores_one(self, cfg, gvecs):
        g, gm = gvecs
        truth = (0.9, 1.3, 0.2)
        spots = geometry.simulate_spots(truth, cfg)
        assert len(spots) >= 8
        obs, omask = make_obs(spots, cfg)
        euler = np.zeros((64, 3), np.float32)
        euler[0] = truth
        score, matched, sim = run_kernel(euler, obs, omask, cfg, g, gm)
        assert float(score[0]) == pytest.approx(1.0)
        assert float(matched[0]) == float(sim[0])

    def test_random_orientations_score_low(self, cfg, gvecs, rng):
        g, gm = gvecs
        spots = geometry.simulate_spots((0.9, 1.3, 0.2), cfg)
        obs, omask = make_obs(spots, cfg)
        euler = rng.uniform(0, 2 * np.pi, (256, 3)).astype(np.float32)
        score, _, _ = run_kernel(euler, obs, omask, cfg, g, gm)
        assert float(jnp.mean(score)) < 0.2

    def test_score_degrades_with_misorientation(self, cfg, gvecs):
        """Completeness decreases (weakly) as we rotate away from truth."""
        g, gm = gvecs
        truth = np.array([0.9, 1.3, 0.2], np.float32)
        spots = geometry.simulate_spots(tuple(truth), cfg)
        obs, omask = make_obs(spots, cfg)
        deltas = np.array([0.0, 0.05, 0.3, 1.0], np.float32)
        euler = np.tile(truth, (64, 1))
        euler[: len(deltas), 0] += deltas
        score, _, _ = run_kernel(euler, obs, omask, cfg, g, gm)
        s = np.asarray(score[: len(deltas)])
        assert s[0] == pytest.approx(1.0)
        assert s[0] >= s[2] and s[0] >= s[3]
        assert s[3] < 0.3

    def test_noisy_observations_still_recover(self, cfg, gvecs, rng):
        """Spot centroids jittered within tolerance: score stays high."""
        g, gm = gvecs
        truth = (2.1, 0.8, 1.7)
        spots = geometry.simulate_spots(truth, cfg)
        noisy = spots.copy()
        noisy[:, :2] += rng.normal(0, 1.0, (len(spots), 2))
        obs, omask = make_obs(noisy, cfg)
        euler = np.zeros((64, 3), np.float32)
        euler[0] = truth
        score, _, _ = run_kernel(euler, obs, omask, cfg, g, gm)
        assert float(score[0]) > 0.9

    def test_two_grain_mixture(self, cfg, gvecs):
        """Observations from two grains: each truth scores ~1 against the
        union (completeness counts *simulated* spots matched)."""
        g, gm = gvecs
        t1, t2 = (0.9, 1.3, 0.2), (2.2, 0.5, 1.0)
        s1 = geometry.simulate_spots(t1, cfg)
        s2 = geometry.simulate_spots(t2, cfg)
        both = np.concatenate([s1, s2], axis=0)
        obs, omask = make_obs(both, cfg)
        euler = np.zeros((64, 3), np.float32)
        euler[0] = t1
        euler[1] = t2
        score, _, _ = run_kernel(euler, obs, omask, cfg, g, gm)
        assert float(score[0]) > 0.95
        assert float(score[1]) > 0.95


class TestPredictedSpots:
    """predicted_spots (kernel path) vs geometry.simulate_spots (numpy)."""

    @given(p1=ANGLE, pp=ANGLE, p2=ANGLE)
    @settings(max_examples=15, deadline=None)
    def test_matches_numpy_forward_model(self, p1, pp, p2):
        cfg = geometry.Config(frame=256, det_dist=1.25e5)
        g = jnp.asarray(geometry.gvectors(cfg))
        gm = jnp.asarray(geometry.gvector_mask(cfg))
        euler = jnp.asarray([[p1, pp, p2]], dtype=jnp.float32)
        spot, valid = fk.predicted_spots(euler, g, gm, cfg)
        got = np.asarray(spot[0])[np.asarray(valid[0]) > 0.5]
        want = geometry.simulate_spots((p1, pp, p2), cfg)
        want = np.column_stack(
            [want[:, 0], want[:, 1], want[:, 2] * cfg.omega_weight]
        )
        # f32 kernel vs f64 numpy can disagree on spots that sit exactly
        # on a validity boundary (|t|=1, panel edge): compare as point
        # sets and allow a small unmatched remainder at the boundary.
        unmatched = 0
        for s in got:
            d = np.linalg.norm(want - s[None, :], axis=1) if len(want) else [np.inf]
            if np.min(d) > 0.5:
                unmatched += 1
        assert unmatched <= max(1, len(got) // 20), (unmatched, len(got))
        assert abs(len(got) - len(want)) <= max(2, len(want) // 10)
