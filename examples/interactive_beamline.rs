//! The end-to-end interactive beamline session — the Fig 7 cross-lab
//! workflow, detector to microstructure, with the paper's headline
//! claim ("three months to under 10 minutes") checked in virtual time
//! and the science verified with real numerics.
//!
//! ```bash
//! make artifacts && cargo run --release --example interactive_beamline
//! ```
//!
//! Pipeline (numbers in the summary table):
//!   (1) detector writes a rotation scan to APS NFS
//!   (2) data reduction on the Orthros cluster (SVI-A workload)
//!   (3) Globus transfer APS -> ALCF, checksummed
//!   (4) metadata catalog registration with provenance
//!   (5) Swift I/O hook stages inputs to 4,096 BG/Q nodes
//!   (6) NF-HEDM stage 2: 100,000 FitOrientation tasks
//!
//! Timing uses paper-scale data (360 x 8 MB raw frames, 577 MB staged
//! set); numerics use a reduced-resolution scan whose ground-truth
//! grain orientations are genuinely recovered through the AOT kernels.

use xstage::catalog::Catalog;
use xstage::cluster::{bgq, orthros, Topology};
use xstage::dataflow::sched::{run_workflow, SchedulerCfg};
use xstage::engine::SimCore;
use xstage::hedm::detector::{Layer, NoiseModel};
use xstage::hedm::fit::{fit_orientation, ArtifactScorer, NativeScorer, ScanCfg};
use xstage::hedm::geometry::{simulate_spots, spot_overlap, Geom};
use xstage::hedm::workloads;
use xstage::metrics::Table;
use xstage::mpisim::Comm;
use xstage::pfs::{Blob, GpfsParams, ParallelFs};
use xstage::runtime::Runtime;
use xstage::staging::{read_phase, staged_plan, HookSpec};
use xstage::transfer::TransferService;

fn main() -> anyhow::Result<()> {
    println!("== Interactive beamline session (Fig 7 workflow) ==\n");
    let mut summary = Table::new(
        "Turnaround: detector to microstructure",
        &["step", "virtual time (s)", "notes"],
    );

    // (1) Detector -> APS NFS: 360 raw frames, 8 MB each, + darks.
    let mut aps = ParallelFs::new();
    for i in 0..360 {
        aps.write(
            format!("/aps/run7/raw/frame_{i:04}.bin"),
            Blob::synthetic(workloads::RAW_FRAME_BYTES, 0x0AF5 + i),
        );
    }
    // Detector streaming overlaps collection; charge the NFS write of
    // the final frames (2.88 GB at ~0.6 GB/s NFS).
    let detector_secs = 360.0 * workloads::RAW_FRAME_BYTES as f64 / 0.6e9;
    summary.row(&[
        "detector -> NFS".into(),
        format!("{detector_secs:.1}"),
        "360 x 8 MB frames".into(),
    ]);

    // (2) Reduction on Orthros (SVI-A): 106 s class.
    let reduce_secs = {
        let mut core = SimCore::new();
        let topo = Topology::build(orthros(), GpfsParams::default(), &mut core.net);
        let comm = Comm::world(&topo.spec);
        let g = workloads::nf_reduce_graph(7);
        run_workflow(&mut core, &topo, &comm, g, SchedulerCfg::default())
            .makespan
            .secs_f64()
    };
    for i in 0..360 {
        aps.write(
            format!("/aps/run7/reduced/r{i:04}.bin"),
            Blob::synthetic(workloads::REDUCED_FRAME_BYTES, 0x2ED + i),
        );
    }
    summary.row(&[
        "reduction (Orthros)".into(),
        format!("{reduce_secs:.1}"),
        "736 images, 320 cores (paper: 106 s)".into(),
    ]);

    // (3)+(5)+(6) run on the ALCF side: one SimCore, time accumulates.
    let nodes = 4096u32;
    let mut core = SimCore::new();
    let topo = Topology::build(bgq(nodes), GpfsParams::default(), &mut core.net);

    let mut globus = TransferService::new(&mut core, TransferService::default_wan_bw(), 11);
    let report = globus.transfer(&mut core, &aps, "/aps/run7/reduced/*.bin", "/alcf/run7")?;
    summary.row(&[
        "Globus APS->ALCF".into(),
        format!("{:.1}", report.seconds),
        format!("{} files, {}", report.files, xstage::units::fmt_bytes(report.bytes)),
    ]);

    // (4) Catalog registration (bookkeeping; negligible time).
    let mut cat = Catalog::new();
    let raw = cat.register("run7-raw", "/aps/run7/raw", 360, 360 * workloads::RAW_FRAME_BYTES);
    let red = cat.register("run7-reduced", "/alcf/run7", 360, report.bytes);
    cat.add_parent(red, raw);
    cat.set_attr(red, "technique", "nf-hedm");
    summary.row(&["catalog".into(), "0.0".into(), "provenance: raw -> reduced".into()]);

    // (5) Stage to every compute node with the I/O hook + params pad
    // to the paper's 577 MB staged working set.
    core.pfs.write(
        "/alcf/run7/params.bin",
        Blob::synthetic(workloads::NF_STAGE2_DATASET_BYTES - report.bytes, 0x9AD),
    );
    let spec = HookSpec::parse("broadcast to /tmp/hedm { /alcf/run7/*.bin }")?;
    let leader = Comm::leader(&topo.spec);
    let world = Comm::world(&topo.spec);
    let t0 = core.now;
    let mut plan = xstage::simtime::plan::Plan::new(0);
    let (manifest, done) = staged_plan(&mut plan, &core.pfs, &topo, &leader, &spec, vec![])?;
    read_phase(&mut plan, &topo, &world, manifest.total_bytes, vec![done]);
    core.submit(plan);
    core.run_to_completion();
    let staging_secs = (core.now - t0).secs_f64();
    summary.row(&[
        format!("I/O hook ({nodes} nodes)"),
        format!("{staging_secs:.1}"),
        format!("{} staged + read", xstage::units::fmt_bytes(manifest.total_bytes)),
    ]);

    // (6) NF stage 2: 100,000 FitOrientation tasks over the machine.
    let t0 = core.now;
    let g = workloads::nf_stage2_graph(
        workloads::NF_STAGE2_GRID_POINTS,
        &manifest.transfers[0].dst,
        13,
    );
    let cfg = SchedulerCfg { cache_inputs: true, ..Default::default() };
    let stats = run_workflow(&mut core, &topo, &world, g, cfg);
    let fit_secs = (core.now - t0).secs_f64();
    summary.row(&[
        "NF stage 2 (BG/Q)".into(),
        format!("{fit_secs:.1}"),
        format!(
            "{} tasks on {} ranks, util {:.0}%",
            stats.tasks_run,
            world.size(),
            stats.utilization * 100.0
        ),
    ]);

    let total =
        detector_secs + reduce_secs + report.seconds + staging_secs + fit_secs;
    summary.row(&["TOTAL".into(), format!("{total:.1}"), "paper: 'under 10 minutes'".into()]);
    print!("\n{}", summary.render());
    assert!(total < 600.0, "turnaround {total} s exceeds the 10-minute claim");

    // Science check: recover a grain orientation through the real
    // kernels (reduced-resolution scan; ground truth known).
    println!("\nscience check: fitting a known grain through the AOT kernels...");
    let (geom, fit, truth) = if Runtime::artifacts_available() {
        let mut rt = Runtime::load(Runtime::default_dir())?;
        let geom = Geom::from_manifest(&rt.manifest.config);
        let layer = Layer::synthesize(4, geom, 99);
        let truth = layer.grains[0].euler;
        let _noise = NoiseModel::default();
        let obs = layer.grains[0].spots.clone();
        let mut scorer = ArtifactScorer::new(&mut rt, &obs);
        (geom, fit_orientation(&mut scorer, &ScanCfg::default())?, truth)
    } else {
        let geom = Geom { frame: 256, det_dist: 1.25e5, ..Geom::default() };
        let layer = Layer::synthesize(4, geom, 99);
        let truth = layer.grains[0].euler;
        let obs = layer.grains[0].spots.clone();
        let mut scorer = NativeScorer::new(geom, &obs);
        (geom, fit_orientation(&mut scorer, &ScanCfg::default())?, truth)
    };
    let overlap =
        spot_overlap(&simulate_spots(fit.euler, &geom), &simulate_spots(truth, &geom), &geom);
    println!(
        "fit confidence {:.2}, truth-pattern overlap {overlap:.2}",
        fit.confidence
    );
    assert!(overlap > 0.9, "fit failed to recover the grain");
    println!("\ninteractive beamline OK: {total:.0} s turnaround (vs months offline)");
    Ok(())
}
