//! Quickstart: stage a dataset with the Swift I/O hook and run a
//! many-task workflow against it.
//!
//! ```bash
//! make artifacts && cargo run --release --example quickstart
//! ```
//!
//! Simulates a 512-node BG/Q allocation: writes a 577 MB dataset to
//! the shared filesystem, stages it to every node's RAM disk with the
//! collective I/O hook, then runs 10,000 analysis tasks that read the
//! staged replica — and prints the phase breakdown the paper's Fig 9
//! defines (Staging, Write, Read) plus the workflow makespan.

use xstage::cluster::{bgq, Topology};
use xstage::dataflow::graph::{Task, TaskGraph};
use xstage::dataflow::sched::{run_workflow, SchedulerCfg};
use xstage::engine::SimCore;
use xstage::mpisim::Comm;
use xstage::pfs::{Blob, GpfsParams};
use xstage::simtime::plan::Plan;
use xstage::staging::{staged_plan, HookSpec};
use xstage::units::{fmt_bw, Duration, MB};
use xstage::util::prng::Pcg64;

fn main() -> anyhow::Result<()> {
    let nodes = 512;
    println!("== xstage quickstart: {nodes}-node BG/Q, 577 MB dataset ==\n");

    // 1. A simulated machine + shared filesystem with a real dataset.
    let mut core = SimCore::new();
    let topo = Topology::build(bgq(nodes), GpfsParams::default(), &mut core.net);
    for i in 0..64 {
        core.pfs.write(
            format!("/projects/HEDM/layer0/f{i:04}.bin"),
            Blob::synthetic(577 * MB / 64, i),
        );
    }

    // 2. The I/O hook spec (Fig 6 syntax), staged on the leader comm.
    let spec = HookSpec::parse(
        "# stage the layer to every node's RAM disk\n\
         broadcast to /tmp/hedm { /projects/HEDM/layer0/*.bin }",
    )?;
    let leader = Comm::leader(&topo.spec);
    let mut plan = Plan::new(0);
    let (manifest, _) = staged_plan(&mut plan, &core.pfs, &topo, &leader, &spec, vec![])?;
    core.submit(plan);
    core.run_to_completion();

    let staged_secs = core.now.secs_f64();
    println!(
        "staged {} files / {} to {} nodes in {:.2} s  (aggregate {})",
        manifest.transfers.len(),
        xstage::units::fmt_bytes(manifest.total_bytes),
        nodes,
        staged_secs,
        fmt_bw(nodes as f64 * manifest.total_bytes as f64 / staged_secs),
    );
    // The data plane is real: verify a replica.
    let orig = core.pfs.read(&manifest.transfers[0].src).unwrap();
    let replica = core.nodes.read(nodes - 1, &manifest.transfers[0].dst).unwrap();
    assert!(replica.same_content(orig));
    println!("replica checksum verified on node {}", nodes - 1);

    // 3. A 10,000-task workflow reading one staged file per task.
    let world = Comm::world(&topo.spec);
    let mut g = TaskGraph::new();
    let mut rng = Pcg64::new(1);
    g.foreach(10_000, |i| {
        Task::compute(format!("fit{i}"), Duration::from_secs_f64(rng.range_f64(20.0, 40.0)))
            .with_input(manifest.transfers[i % 64].dst.clone(), None)
    });
    let stats = run_workflow(&mut core, &topo, &world, g, SchedulerCfg::default());
    println!(
        "\nworkflow: {} tasks on {} ranks -> makespan {:.1} s (utilization {:.0}%)",
        stats.tasks_run,
        world.size(),
        stats.makespan.secs_f64(),
        stats.utilization * 100.0
    );
    println!(
        "staged reads {} | unstaged (GPFS) reads {}",
        xstage::units::fmt_bytes(stats.staged_read_bytes),
        xstage::units::fmt_bytes(stats.unstaged_read_bytes),
    );
    assert_eq!(stats.unstaged_read_bytes, 0, "everything came from the RAM disk");
    println!("\nquickstart OK (virtual time {:.1} s)", core.now.secs_f64());
    Ok(())
}
