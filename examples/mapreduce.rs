//! The Fig 4/5 MapReduce pattern, barrier-free.
//!
//! ```bash
//! cargo run --release --example mapreduce
//! ```
//!
//! Builds the paper's Swift MapReduce (a `foreach` map phase + a
//! recursive pairwise merge) as a task graph, runs it on the simulated
//! Orthros cluster, and demonstrates the property the paper calls out:
//! "this dataflow expression of simplified MapReduce does not have a
//! barrier between the map and reduce phases" — merges complete while
//! slow maps are still running.

use xstage::cluster::{orthros, Topology};
use xstage::dataflow::mapreduce;
use xstage::dataflow::sched::{run_workflow, SchedulerCfg};
use xstage::engine::SimCore;
use xstage::mpisim::Comm;
use xstage::pfs::GpfsParams;
use xstage::units::Duration;
use xstage::util::prng::Pcg64;

fn main() {
    let n = 64;
    println!("== MapReduce (Fig 4/5): {n} maps + pairwise merge tree ==\n");
    let mut rng = Pcg64::new(7);
    // A straggler-heavy map phase: most maps 2-6 s, a few 40+ s.
    let map_secs: Vec<f64> =
        (0..n).map(|_| rng.log_uniform(2.0, 60.0)).collect();
    let (graph, root) = mapreduce::build(
        n,
        |i| Duration::from_secs_f64(map_secs[i]),
        |_| Duration::from_secs_f64(1.0),
    );
    println!(
        "graph: {} tasks ({} maps, {} merges), critical path {:.1} s",
        graph.len(),
        n,
        graph.len() - n,
        graph.critical_path().secs_f64()
    );

    let mut core = SimCore::new();
    let topo = Topology::build(orthros(), GpfsParams::default(), &mut core.net);
    let comm = Comm::world(&topo.spec);
    let stats = run_workflow(&mut core, &topo, &comm, graph, SchedulerCfg::default());

    // When did the first merge finish vs the last map?
    let first_merge = (n..stats.completion.len())
        .map(|i| stats.completion[i].secs_f64())
        .fold(f64::INFINITY, f64::min);
    let last_map = (0..n)
        .map(|i| stats.completion[i].secs_f64())
        .fold(0.0f64, f64::max);
    println!("\nfirst merge done at {first_merge:.1} s");
    println!("last map    done at {last_map:.1} s");
    println!("root merge  done at {:.1} s", stats.completion[root.0].secs_f64());
    assert!(
        first_merge < last_map,
        "reduction should overlap the map phase (no barrier)"
    );
    println!("\nno barrier between map and reduce: OK");
    println!("makespan {:.1} s", stats.makespan.secs_f64());
}
