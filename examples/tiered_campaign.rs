//! Example: a memory-overflow campaign that stays off GPFS thanks to
//! the node-local SSD tier.
//!
//! ```bash
//! cargo run --release --example tiered_campaign
//! ```
//!
//! Three 64 MB datasets ping-pong through a 96 MB per-node RAM staging
//! slice on an Orthros-class cluster — the combined working set does
//! not fit, so every activation displaces somebody. Pre-tiering, each
//! displacement destroyed the replica and the next re-open paid a full
//! GPFS re-stage; with the SSD tier, eviction *demotes* and re-opens
//! *promote* at local-disk bandwidth. The session therefore touches
//! the shared filesystem exactly once per dataset — the warmup stage —
//! and never again, which the example asserts.

use xstage::catalog::Catalog;
use xstage::cluster::{orthros, Topology};
use xstage::dataflow::graph::{Task, TaskGraph};
use xstage::dataflow::sched::{run_workflow, SchedulerCfg};
use xstage::engine::SimCore;
use xstage::metrics::Table;
use xstage::mpisim::Comm;
use xstage::pfs::{Blob, GpfsParams};
use xstage::staging::{HookSpec, Residency};
use xstage::units::{fmt_bytes, Duration, MB};

const DATASETS: usize = 3;
const FILES: usize = 4;
const FILE_BYTES: u64 = 16 * MB;
const DATASET_BYTES: u64 = FILES as u64 * FILE_BYTES;
/// Holds 1.5 datasets: the 192 MB working set overflows RAM...
const RAM_SLICE: u64 = 96 * MB;
/// ...but RAM + SSD holds everything with room to spare.
const SSD_SLICE: u64 = 256 * MB;
/// The interactive activation order: first cycle is the cold warmup,
/// every later activation re-opens an evicted dataset.
const SCHEDULE: &[usize] = &[0, 1, 2, 0, 1, 2, 0, 2, 1, 0];

fn analysis_graph(comm: &Comm, ds: usize, round: usize) -> TaskGraph {
    let mut g = TaskGraph::new();
    g.foreach(64, |i| {
        let f = (i + round) % FILES;
        Task::compute(format!("r{round}/ds{ds}/fit{i}"), Duration::from_secs(3))
            .with_input(format!("/tmp/tc{ds}/f{f:02}.bin"), None)
    });
    g
}

fn main() -> anyhow::Result<()> {
    println!("== Tiered campaign: RAM overflow absorbed by the SSD tier ==\n");
    let mut core = SimCore::new();
    let mut machine = orthros();
    machine.nodes = 4;
    let topo = Topology::build(machine, GpfsParams::default(), &mut core.net);
    topo.apply_storage_budgets(&mut core);
    core.nodes.set_capacity(Some(RAM_SLICE));
    core.nodes.set_ssd_capacity(Some(SSD_SLICE));
    let leader = Comm::leader(&topo.spec);
    let world = Comm::world(&topo.spec);

    let mut catalog = Catalog::new();
    let mut res = Residency::new();
    let mut ids = Vec::new();
    for d in 0..DATASETS {
        for f in 0..FILES {
            core.pfs.write(
                format!("/projects/tiered/c{d}/f{f:02}.bin"),
                Blob::synthetic(FILE_BYTES, 0x71E2 + (d * 100 + f) as u64),
            );
        }
        let id = catalog.register(
            format!("tiered-c{d}"),
            format!("/projects/tiered/c{d}"),
            FILES as u64,
            DATASET_BYTES,
        );
        let spec = HookSpec::parse(&format!(
            "broadcast to /tmp/tc{d} {{ /projects/tiered/c{d}/*.bin }}"
        ))?;
        res.bind(id, spec);
        ids.push(id);
    }
    assert!(DATASETS as u64 * DATASET_BYTES > RAM_SLICE, "no overflow, no story");

    let mut table = Table::new(
        format!(
            "Activations — {DATASETS} x {} datasets, {} RAM + {} SSD per node",
            fmt_bytes(DATASET_BYTES),
            fmt_bytes(RAM_SLICE),
            fmt_bytes(SSD_SLICE),
        ),
        &["round", "dataset", "staged (GPFS)", "promoted (SSD)", "RAM hits"],
    );
    for (round, &d) in SCHEDULE.iter().enumerate() {
        let m = res.stage_dataset(&mut core, &topo, &leader, ids[d])?;
        table.row(&[
            round.to_string(),
            format!("c{d}"),
            fmt_bytes(m.staged_bytes),
            fmt_bytes(m.promoted_bytes),
            m.hits.len().to_string(),
        ]);
        // Warmup cycle aside, the shared FS is never touched again:
        // everything is served from node RAM or promoted from the SSD.
        if round >= DATASETS {
            assert_eq!(
                m.staged_bytes, 0,
                "round {round}: re-open of c{d} re-staged from GPFS despite the SSD tier"
            );
        }
        let g = analysis_graph(&world, d, round);
        run_workflow(&mut core, &topo, &world, g, SchedulerCfg::default());
        res.unpin_dataset(&mut core, ids[d]);
    }
    print!("\n{}", table.render());

    assert_eq!(
        res.stats.staged_bytes,
        DATASETS as u64 * DATASET_BYTES,
        "GPFS moved exactly one warmup stage per dataset"
    );
    assert!(res.stats.promoted_bytes > 0, "no promotions — the tier never engaged");
    assert_eq!(core.node_write_rejections(), 0);
    assert!(core.residency.mirrors(&core.nodes), "residency mirror diverged");

    println!(
        "\ntiered campaign OK: {} activations, {} staged from GPFS (warmup only), \
         {} promoted from SSD, {} demoted under pressure, virtual session {:.1} s",
        SCHEDULE.len(),
        fmt_bytes(res.stats.staged_bytes),
        fmt_bytes(res.stats.promoted_bytes),
        fmt_bytes(core.metrics.bytes("node.demote")),
        core.now.secs_f64(),
    );
    Ok(())
}
