//! NF-HEDM on one layer — the Fig 2 analog, with the full numeric
//! pipeline and verified recovery.
//!
//! ```bash
//! make artifacts && cargo run --release --example nf_hedm_layer
//! ```
//!
//! Synthesizes a gold-wire-like cross-section with 4 grains of known
//! orientation, renders its rotation-series diffraction frames (real
//! pixels), then runs the production path end to end:
//!
//!   frames -> dark median -> stage-1 reduction (AOT Pallas median
//!   kernel via PJRT) -> connected-component peak extraction ->
//!   stage-2 FitOrientation scans (AOT fit kernel) on a hex grid
//!
//! and verifies every fitted grid point recovered its grain's
//! ground-truth orientation (pattern overlap > 0.9). The paper shows
//! this qualitatively as the colored grain map of Fig 2; with a
//! synthetic sample we can *assert* it.

use xstage::hedm::ccl::find_peaks;
use xstage::hedm::detector::{render_dark, render_frame, Layer, NoiseModel};
use xstage::hedm::fit::{fit_orientation, ArtifactScorer, NativeScorer, ScanCfg};
use xstage::hedm::geometry::{simulate_spots, spot_overlap, Geom, Spot};
use xstage::hedm::reduce::{
    dark_median_native, reduce_frame_artifact, reduce_frame_native, ReduceParams,
};
use xstage::runtime::Runtime;
use xstage::util::prng::Pcg64;

/// Recover the spot list of one grain's scan by rendering + reducing
/// every rotation frame and extracting peak centroids.
fn stage1(
    rt: &mut Option<Runtime>,
    geom: &Geom,
    spots: &[Spot],
    noise: &NoiseModel,
    seed: u64,
) -> Vec<Spot> {
    let mut rng = Pcg64::new(seed);
    // Dark stack -> per-pixel median.
    let darks: Vec<Vec<f32>> = (0..4).map(|_| render_dark(geom, noise, &mut rng)).collect();
    let dark = dark_median_native(&darks);
    let params = ReduceParams::default();
    let w = 360.0 / geom.omega_steps as f64;
    let mut observed = Vec::new();
    for step in 0..geom.omega_steps {
        let frame = render_frame(spots, geom, noise, step, &mut rng);
        let reduced = match rt {
            Some(rt) => reduce_frame_artifact(rt, &frame, &dark).expect("artifact reduce"),
            None => reduce_frame_native(&frame, &dark, geom.frame, &params),
        };
        if reduced.count == 0 {
            continue;
        }
        let omega = -180.0 + (step as f64 + 0.5) * w;
        for p in find_peaks(&reduced.mask, &reduced.sub, geom.frame, 2) {
            observed.push(Spot { u: p.u, v: p.v, omega_deg: omega });
        }
    }
    observed
}

fn main() -> anyhow::Result<()> {
    let use_artifacts = Runtime::artifacts_available();
    let mut rt = if use_artifacts {
        Some(Runtime::load(Runtime::default_dir())?)
    } else {
        eprintln!("note: no artifacts — falling back to the native pipeline");
        None
    };
    // 360 rotation steps (the paper's "360 to 1,440 angles"): 1-degree
    // omega bins keep the quantisation error (~0.5 deg * 4 px/deg = 2 px)
    // inside the 6 px match tolerance. Coarser scans break stage 2.
    let geom = match &rt {
        Some(rt) => Geom::from_manifest(&rt.manifest.config),
        None => Geom { frame: 256, det_dist: 1.25e5, ..Geom::default() },
    };
    println!(
        "== NF-HEDM layer (Fig 2 analog): 4 grains, {} frames of {}^2, {} backend ==\n",
        geom.omega_steps,
        geom.frame,
        if use_artifacts { "PJRT artifact" } else { "native" }
    );

    let layer = Layer::synthesize(4, geom, 2024);
    let noise = NoiseModel::default();
    let grid = layer.hex_grid(38.0); // ~600 points, like Fig 2's 601
    println!("hex grid: {} points over a 1 mm section", grid.len());

    // Stage 1 per grain (the line-focused beam resolves the section
    // spatially: a grid point sees its grain's diffraction signal).
    let mut grain_obs: Vec<Vec<Spot>> = Vec::new();
    for g in &layer.grains {
        let obs = stage1(&mut rt, &geom, &g.spots, &noise, 100 + g.id as u64);
        println!(
            "grain {}: {} true spots -> {} recovered by reduction+CCL",
            g.id,
            g.spots.len(),
            obs.len()
        );
        assert!(
            obs.len() as f64 >= 0.8 * g.spots.len() as f64,
            "stage 1 lost too many spots"
        );
        grain_obs.push(obs);
    }

    // Stage 2: FitOrientation at sampled grid points (2 per grain).
    let scan = ScanCfg::default();
    let mut fitted = 0usize;
    let mut correct = 0usize;
    for gid in 0..layer.grains.len() {
        let pts: Vec<_> = grid.iter().filter(|(_, _, o)| *o == gid).take(2).collect();
        for (x, y, _) in pts {
            let fit = match &mut rt {
                Some(rt) => {
                    let mut scorer = ArtifactScorer::new(rt, &grain_obs[gid]);
                    fit_orientation(&mut scorer, &scan)?
                }
                None => {
                    let mut scorer = NativeScorer::new(geom, &grain_obs[gid]);
                    fit_orientation(&mut scorer, &scan)?
                }
            };
            let truth = layer.grains[gid].euler;
            let overlap = spot_overlap(
                &simulate_spots(fit.euler, &geom),
                &simulate_spots(truth, &geom),
                &geom,
            );
            fitted += 1;
            if overlap > 0.9 {
                correct += 1;
            }
            println!(
                "point ({x:6.1}, {y:6.1}) grain {gid}: confidence {:.2}, truth overlap {:.2}",
                fit.confidence, overlap
            );
        }
    }
    println!("\ngrain map: {correct}/{fitted} grid points recovered their grain's orientation");
    assert!(correct == fitted, "orientation recovery failed");
    println!("NF-HEDM layer OK");
    Ok(())
}
