//! FF-HEDM on a volume — the Fig 3 analog: grain-center indexing.
//!
//! ```bash
//! make artifacts && cargo run --release --example ff_hedm_volume
//! ```
//!
//! A box beam illuminates a volume containing several grains; the
//! rotation scan records every grain's diffraction spots mixed on the
//! same detector. Stage 1 characterises the spots; stage 2 *indexes*
//! them — greedily assigning spots to grains by orientation fitting —
//! recovering one (orientation, spot-count) entry per grain, the dots
//! of Fig 3. Ground truth lets us assert every grain is found.

use xstage::hedm::ff::{count_recovered, index_grains_artifact, index_grains_native, IndexCfg};
use xstage::hedm::detector::Layer;
use xstage::hedm::geometry::Geom;
use xstage::runtime::Runtime;

fn main() -> anyhow::Result<()> {
    let grains = 6;
    let use_artifacts = Runtime::artifacts_available();
    let geom = if use_artifacts {
        Geom::from_manifest(&Runtime::load(Runtime::default_dir())?.manifest.config)
    } else {
        Geom { frame: 256, det_dist: 1.25e5, ..Geom::default() }
    };
    println!(
        "== FF-HEDM volume (Fig 3 analog): {grains} grains, {} backend ==\n",
        if use_artifacts { "PJRT artifact" } else { "native" }
    );

    let layer = Layer::synthesize(grains, geom, 3031);
    let obs = layer.all_spots();
    println!("volume scan: {} spots from {} grains (mixed)", obs.len(), grains);

    let cfg = IndexCfg { max_grains: grains + 4, ..Default::default() };
    let indexed = if use_artifacts {
        let mut rt = Runtime::load(Runtime::default_dir())?;
        index_grains_artifact(&mut rt, &obs, &cfg)?
    } else {
        index_grains_native(&obs, geom, &cfg)
    };

    println!("\nindexed {} grains:", indexed.len());
    for (i, g) in indexed.iter().enumerate() {
        println!(
            "  grain {i}: euler [{:.3}, {:.3}, {:.3}]  confidence {:.2}  claimed {} spots",
            g.fit.euler[0], g.fit.euler[1], g.fit.euler[2], g.fit.confidence, g.claimed
        );
    }

    let truth: Vec<[f64; 3]> = layer.grains.iter().map(|g| g.euler).collect();
    let recovered = count_recovered(&indexed, &truth, &geom);
    println!("\nrecovered {recovered}/{grains} ground-truth grains");
    assert_eq!(recovered, grains, "indexing missed grains");
    println!("FF-HEDM volume OK");
    Ok(())
}
